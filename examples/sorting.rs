//! In-memory sorting demo (experiment E10): a bank of rows each sorting its
//! own 16-element vector, serial vs partitioned.
//!
//! Run: `cargo run --release --example sorting`

use anyhow::Result;
use partition_pim::algorithms::sort::{build_sorter_partitioned, build_sorter_serial};
use partition_pim::backend::ExecPipeline;
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::figures;

fn main() -> Result<()> {
    // 16 elements of 6 bits per row, one element per partition; 32 rows sort
    // 32 independent vectors simultaneously.
    let geom = Geometry::new(512, 16, 32)?;
    let sorter = build_sorter_partitioned(geom, 6)?;
    let mut xb = Crossbar::new(geom, GateSet::NotNor);

    let mut seed = 2026u64;
    let mut inputs = Vec::new();
    for r in 0..32 {
        let vals: Vec<u64> = (0..16)
            .map(|_| {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (seed >> 40) % 64
            })
            .collect();
        sorter.load(&mut xb.state, r, &vals)?;
        inputs.push(vals);
    }

    sorter.program.execute(&mut ExecPipeline::direct(&mut xb))?;
    let stats = sorter.program.stats();
    println!("partitioned bitonic sort: 32 rows x 16 elements in {} cycles\n", stats.cycles);
    for r in [0usize, 1] {
        let sorted = sorter.read(&xb.state, r)?;
        println!("row {r}:  {:?}\n    ->  {:?}", inputs[r], sorted);
        let mut expect = inputs[r].clone();
        expect.sort_unstable();
        anyhow::ensure!(sorted == expect, "row {r} not sorted");
    }
    for r in 0..32 {
        let sorted = sorter.read(&xb.state, r)?;
        let mut expect = inputs[r].clone();
        expect.sort_unstable();
        anyhow::ensure!(sorted == expect, "row {r} not sorted");
    }
    println!("\nall 32 rows verified sorted");

    // Serial baseline comparison.
    let ser = build_sorter_serial(Geometry::new(1024, 1, 1)?, 16, 6)?;
    println!(
        "\nserial baseline: {} cycles  ->  partition speedup {:.2}x",
        ser.program.stats().cycles,
        ser.program.stats().cycles as f64 / stats.cycles as f64
    );

    println!("\nspeedup vs element count:");
    for r in figures::sort_table(6)? {
        println!("  {:>2} elements: {:>6.2}x", r.elems, r.speedup);
    }
    Ok(())
}
