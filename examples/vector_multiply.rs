//! End-to-end driver (experiment E13): a PIM service bank multiplying real
//! vector workloads under all four designs, reporting the paper's headline
//! metrics — latency (simulated cycles), throughput, and control traffic.
//!
//! This exercises every layer: job batching (coordinator) → per-cycle
//! control-message encoding (controller) → periphery decode (half-gates /
//! opcode generator / range generator) → stateful-logic execution
//! (crossbar simulator) → result readback, with full metric accounting.
//!
//! Run: `cargo run --release --example vector_multiply`

use anyhow::Result;
use partition_pim::coordinator::{PimService, ServiceConfig, WorkloadKind};
use partition_pim::isa::models::ModelKind;
use std::time::Instant;

fn main() -> Result<()> {
    let n_jobs = 6;
    let job_len = 512;
    println!("workload: {n_jobs} jobs x {job_len} element-wise 32-bit multiplications");
    println!("bank: 4 crossbars x 64 rows\n");
    println!(
        "{:<11} {:>9} {:>14} {:>14} {:>14} {:>12}",
        "model", "verified", "cycles/elem", "bits/elem", "mults/s", "speedup"
    );

    let mut baseline_cycles_per_elem = None;
    for model in [ModelKind::Baseline, ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model,
            n_crossbars: 4,
            rows: 64,
            ..Default::default()
        })?;
        let mut seed = 0x1234_5678_9abc_def0u64;
        let mut rnd = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed & 0xffff_ffff
        };
        let t0 = Instant::now();
        let mut verified = 0usize;
        // All jobs are submitted before any result is awaited: the
        // scheduler keeps every crossbar busy across job boundaries.
        let mut pending = Vec::new();
        for _ in 0..n_jobs {
            let a: Vec<u64> = (0..job_len).map(|_| rnd()).collect();
            let b: Vec<u64> = (0..job_len).map(|_| rnd()).collect();
            let handle = svc.submit(&a, &b)?;
            pending.push((a, b, handle));
        }
        for (a, b, handle) in pending {
            let res = handle.wait()?;
            let vals = res.try_scalars()?;
            for i in 0..job_len {
                anyhow::ensure!(vals[i] == a[i] * b[i], "wrong product");
                verified += 1;
            }
        }
        let wall = t0.elapsed();
        let stats = svc.shutdown();
        let elems = stats.elements as f64;
        // Latency: a batch of `rows` elements shares one program run, so the
        // per-element figure is cycles/batch ÷ rows — the amortized view.
        let cycles_per_elem = stats.metrics.cycles as f64 / elems;
        let speedup = match baseline_cycles_per_elem {
            None => {
                baseline_cycles_per_elem = Some(cycles_per_elem);
                1.0
            }
            Some(base) => base / cycles_per_elem,
        };
        println!(
            "{:<11} {:>9} {:>14.1} {:>14.1} {:>14.0} {:>11.2}x",
            model.name(),
            verified,
            cycles_per_elem,
            stats.metrics.control_bits as f64 / elems,
            elems / wall.as_secs_f64(),
            speedup
        );
    }
    println!("\n(expected shape — Figure 6: unlimited ≈ standard > minimal speedups ~9-11x over baseline;");
    println!(" control bits/elem highest for unlimited, lowest for minimal among partitioned models)");
    Ok(())
}
