//! Reliability sweep: stuck-at cell faults vs end-to-end result corruption.
//!
//! The paper's companion work ([13], *Making Memristive Processing-in-Memory
//! Reliable*) motivates fault tolerance for stateful logic; this driver
//! quantifies the raw vulnerability of the partitioned multiplier: inject
//! stuck-at faults at increasing cell-failure rates, run full 32-bit
//! multiplications, and measure the fraction of wrong products.
//!
//! Run: `cargo run --release --example reliability`

use anyhow::Result;
use partition_pim::algorithms::multpim::{build_multpim, MultPimVariant};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::faults::{run_with_faults, FaultMap};
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;

fn main() -> Result<()> {
    let geom = Geometry::paper(32)?;
    let mult = build_multpim(geom, MultPimVariant::Plain)?;
    println!("fault-rate sweep: 32 rows x 32-bit multiplication, stuck-at cell faults\n");
    println!("{:>12} {:>8} {:>14} {:>12}", "cell rate", "faults", "wrong products", "error rate");

    let mut seed = 0xfau64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        seed & 0xffff_ffff
    };

    for &rate in &[0.0, 1e-5, 1e-4, 5e-4, 1e-3, 5e-3] {
        let mut wrong = 0usize;
        let mut total = 0usize;
        let mut n_faults = 0usize;
        for trial in 0..4u64 {
            let faults = FaultMap::random(geom.rows, geom.n, rate, 1 + trial * 7919);
            n_faults += faults.faults.len();
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            let cases: Vec<(u64, u64)> = (0..geom.rows).map(|_| (rnd(), rnd())).collect();
            for (r, &(a, b)) in cases.iter().enumerate() {
                mult.load(&mut xb.state, r, a, b)?;
            }
            run_with_faults(&mut xb, &mult.program.ops, &faults)?;
            for (r, &(a, b)) in cases.iter().enumerate() {
                total += 1;
                if mult.read_product(&xb.state, r)? != a * b {
                    wrong += 1;
                }
            }
        }
        println!("{:>12.0e} {:>8} {:>14} {:>11.1}%", rate, n_faults / 4, wrong, 100.0 * wrong as f64 / total as f64);
    }
    println!("\n(zero faults -> zero errors; with ~23 of 32 intra columns live per");
    println!(" partition, roughly 2/3 of random cell faults corrupt a product —");
    println!(" the quantitative motivation for remapping/ECC in [13])");
    Ok(())
}
