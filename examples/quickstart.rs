//! Quickstart: the PartitionPIM public API in five minutes.
//!
//! Builds a partitioned crossbar, runs serial / parallel / semi-parallel
//! stateful-logic operations directly and through the full control-message
//! pipeline, prints the Table-1 opcodes, and runs a NOR full adder across
//! all rows at once.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use partition_pim::algorithms::program::{emit_fa_serial, Builder};
use partition_pim::backend::{ExecPipeline, PimBackend};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::encode::message_bits;
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::opcode::Opcode;
use partition_pim::isa::operation::{GateOp, Operation};

fn main() -> Result<()> {
    // An n=256 crossbar with k=8 partitions, 8 rows (each row computes
    // independently — this is the throughput axis).
    let geom = Geometry::new(256, 8, 8)?;
    let mut xb = Crossbar::new(geom, GateSet::NotNor);
    println!("crossbar: n={} bitlines, k={} partitions (m={}), {} rows\n", geom.n, geom.k, geom.m(), geom.rows);

    // --- Table 1: the half-gate opcodes -----------------------------------
    println!("Table 1 — per-partition opcodes:");
    for i in 0..8u8 {
        println!("  {i:03b}  {}", Opcode::from_index(i));
    }

    // --- One parallel operation: k NOR gates in a single cycle ------------
    xb.state.fill_random(42);
    let op = Operation::Gates((0..geom.k).map(|p| GateOp::nor(geom.col(p, 0), geom.col(p, 1), geom.col(p, 3))).collect());
    xb.execute(&op)?;
    println!("\nparallel op: {} NOR gates in 1 cycle (cycles={})", op.gate_count(), xb.metrics.cycles);

    // --- The same cycle through each model's wire pipeline ----------------
    // ExecPipeline::wire encodes the cycle to its bit-exact control message,
    // decodes it through the periphery model, and executes it — metering the
    // control traffic at the decode boundary.
    println!("\ncontrol messages for that cycle:");
    let mut total_control_bits = 0;
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let mut pipe = ExecPipeline::wire(model, &mut xb);
        pipe.run_op(&op)?;
        let stats = pipe.stats();
        println!("  {:<10} {:>4} bits (formula: {})", model.name(), stats.control_bits, message_bits(model, &geom));
        total_control_bits += stats.control_bits;
    }
    println!("  total control traffic so far: {total_control_bits} bits");

    // --- A full adder over every row at once ------------------------------
    let mut b = Builder::new(geom, GateSet::NotNor);
    let scratch: Vec<usize> = (10..20).collect();
    let mut init = scratch.clone();
    init.extend([5, 6]);
    b.init1(init)?;
    emit_fa_serial(&mut b, 0, 1, 2, 5, 6, &scratch)?; // s=col5, cout=col6
    let fa = b.finish("quickstart_fa");

    let mut xb2 = Crossbar::new(geom, GateSet::NotNor);
    for r in 0..8 {
        xb2.state.set(r, 0, r & 1 == 1);
        xb2.state.set(r, 1, r & 2 != 0);
        xb2.state.set(r, 2, r & 4 != 0);
    }
    fa.execute(&mut ExecPipeline::direct(&mut xb2))?;
    println!("\nfull adder, all 8 input combinations in 8 rows, {} cycles:", fa.stats().cycles);
    for r in 0..8 {
        println!(
            "  a={} b={} cin={}  ->  s={} cout={}",
            r & 1,
            (r >> 1) & 1,
            (r >> 2) & 1,
            xb2.state.get(r, 5) as u8,
            xb2.state.get(r, 6) as u8
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
