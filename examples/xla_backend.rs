//! The three-layer stack end to end (experiment E14): run a full MultPIM
//! multiplication on (a) the bit-packed rust simulator and (b) the
//! AOT-compiled JAX/Pallas gate-step kernel through PJRT, and verify the
//! final crossbar states agree bit-for-bit.
//!
//! Requires `make artifacts` first.
//! Run: `cargo run --release --example xla_backend`

use anyhow::{Context, Result};
use partition_pim::algorithms::multpim::{build_multpim, MultPimVariant};
use partition_pim::backend::{ExecPipeline, PimBackend};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::runtime::XlaCrossbar;
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    let geom = Geometry::new(256, 8, 16)?;
    let mult = build_multpim(geom, MultPimVariant::Plain)?;
    println!("program: {} ({} cycles, {} gates)", mult.program.name, mult.program.stats().cycles, mult.program.stats().gates);

    let mut sim = Crossbar::new(geom, GateSet::NotNor);
    let cases: Vec<(u64, u64)> = (0..16).map(|i| ((i * 13 + 7) % 256, (i * 29 + 3) % 256)).collect();
    for (r, &(a, b)) in cases.iter().enumerate() {
        mult.load(&mut sim.state, r, a, b)?;
    }

    let mut xla = XlaCrossbar::new(geom, Path::new("artifacts"))
        .context("loading artifacts/step_r16_c256_g8.hlo.txt — run `make artifacts` (and build with `--features xla`)")?;
    xla.load_state(&sim.state)?;

    // One program, one pipeline API, two physical backends.
    let t = Instant::now();
    mult.program.execute(&mut ExecPipeline::direct(&mut sim))?;
    println!("bit-packed simulator: {:?}", t.elapsed());

    let t = Instant::now();
    mult.program.execute(&mut ExecPipeline::direct(&mut xla))?;
    println!("XLA/PJRT backend:     {:?}", t.elapsed());

    anyhow::ensure!(xla.state_bits()? == sim.state, "backends diverged");
    for (r, &(a, b)) in cases.iter().enumerate() {
        let p = mult.read_product(&sim.state, r)?;
        anyhow::ensure!(p == a * b, "bad product");
        if r < 4 {
            println!("row {r}: {a} x {b} = {p}");
        }
    }
    println!("... all 16 rows verified; backends agree bit-for-bit");
    Ok(())
}
