//! Regenerate the paper's Figure 6 (experiments E6-E9) as a table and a
//! CSV (`figure6.csv`) for plotting.
//!
//! Run: `cargo run --release --example figure6`

use anyhow::Result;
use partition_pim::figures;

fn main() -> Result<()> {
    let rows = figures::figure6()?;
    println!("Figure 6 — 32-bit multiplication under each partition design\n");
    println!(
        "{:<11} {:>8} {:>9} {:>10} {:>8} {:>10} {:>8} {:>9}",
        "model", "cycles", "speedup", "msg bits", "ctrl x", "memrist.", "area x", "energy x"
    );
    for r in &rows {
        println!(
            "{:<11} {:>8} {:>8.2}x {:>10} {:>7.1}x {:>10} {:>7.2}x {:>8.2}x",
            r.model.name(),
            r.stats.cycles,
            r.speedup_vs_serial,
            r.message_bits,
            r.control_overhead,
            r.stats.footprint_cols,
            r.area_ratio,
            r.energy_ratio
        );
    }

    let mut csv = String::from("model,cycles,speedup,msg_bits,control_overhead,memristors,area_ratio,gates,energy_ratio\n");
    for r in &rows {
        csv.push_str(&format!(
            "{},{},{:.4},{},{:.4},{},{:.4},{},{:.4}\n",
            r.model.name(),
            r.stats.cycles,
            r.speedup_vs_serial,
            r.message_bits,
            r.control_overhead,
            r.stats.footprint_cols,
            r.area_ratio,
            r.stats.gates,
            r.energy_ratio
        ));
    }
    std::fs::write("figure6.csv", &csv)?;
    println!("\nwrote figure6.csv");

    println!("\npaper values for comparison:");
    println!("  speedups     11.3x / 9.2x / 8.6x (unlimited / standard / minimal)");
    println!("  control      607 / 79 / 36 bits (20.2x / 2.6x / 1.2x of the 30-bit baseline)");
    println!("  area         ~1.4x, energy ~2.1x");
    Ok(())
}
