"""L1 performance analysis: VMEM footprint + MXU-utilization *estimates*
for the Pallas gate-step kernel.

``interpret=True`` gives CPU-numpy timings only (not a TPU proxy), so the
kernel is tuned structurally: this module computes, per BlockSpec
configuration, the quantities that determine real-TPU performance —
VMEM bytes per block, MXU FLOPs, HBM traffic, arithmetic intensity and a
systolic-array utilization estimate. Results are recorded in
EXPERIMENTS.md §Perf.

Usage: ``python -m compile.analysis`` (from python/).
"""

from __future__ import annotations

from dataclasses import dataclass

# TPU-generation constants (v4-class, bf16): 128x128 MXU, ~16 MiB VMEM/core.
MXU_DIM = 128
VMEM_BYTES = 16 * 1024 * 1024
HBM_GBPS = 1200e9
MXU_FLOPS = 275e12  # bf16 peak


@dataclass
class StepAnalysis:
    rows: int
    cols: int
    gates: int
    block_rows: int
    dtype_bytes: int = 4

    @property
    def vmem_block_bytes(self) -> int:
        """State block in + out, three selector matrices, mode row, and the
        [Rb, G] intermediates."""
        state = 2 * self.block_rows * self.cols * self.dtype_bytes
        sels = 3 * self.cols * self.gates * self.dtype_bytes
        inter = 3 * self.block_rows * self.gates * self.dtype_bytes
        mode = self.gates * self.dtype_bytes
        return state + sels + inter + mode

    @property
    def mxu_flops(self) -> int:
        """Three matmuls: two gathers [Rb,C]@[C,G] and one scatter
        [Rb,G]@[G,C]."""
        return 3 * 2 * self.block_rows * self.cols * self.gates * (self.rows // self.block_rows)

    @property
    def vpu_flops(self) -> int:
        """Elementwise NOR + output blend."""
        per_block = 4 * self.block_rows * self.gates + 3 * self.block_rows * self.cols
        return per_block * (self.rows // self.block_rows)

    @property
    def hbm_bytes(self) -> int:
        """State read + write once per cycle; selectors once (replicated
        from VMEM across blocks after first load in a fused scan)."""
        state = 2 * self.rows * self.cols * self.dtype_bytes
        sels = 3 * self.cols * self.gates * self.dtype_bytes
        return state + sels

    @property
    def arithmetic_intensity(self) -> float:
        return (self.mxu_flops + self.vpu_flops) / self.hbm_bytes

    @property
    def mxu_utilization(self) -> float:
        """Fraction of the 128x128 systolic array the matmul shapes keep
        busy: the gather contraction is C (full), but the output tile is
        [Rb, G] — G < 128 idles (128-G)/128 of the array columns."""
        row_fill = min(self.block_rows, MXU_DIM) / MXU_DIM
        col_fill = min(self.gates, MXU_DIM) / MXU_DIM
        return row_fill * col_fill

    @property
    def memory_bound(self) -> bool:
        machine_balance = MXU_FLOPS / HBM_GBPS
        return self.arithmetic_intensity < machine_balance

    def report(self) -> str:
        return (
            f"step r{self.rows} c{self.cols} g{self.gates} (block_rows={self.block_rows}):\n"
            f"  VMEM/block        {self.vmem_block_bytes / 1024:.1f} KiB"
            f"  ({100 * self.vmem_block_bytes / VMEM_BYTES:.2f}% of VMEM)\n"
            f"  MXU flops/cycle   {self.mxu_flops:,}\n"
            f"  HBM bytes/cycle   {self.hbm_bytes:,}\n"
            f"  arith intensity   {self.arithmetic_intensity:.2f} flop/byte"
            f"  ({'memory' if self.memory_bound else 'compute'}-bound)\n"
            f"  MXU utilization   {100 * self.mxu_utilization:.1f}%"
            f"  (output tile {min(self.block_rows, MXU_DIM)}x{self.gates} on a {MXU_DIM}x{MXU_DIM} array)\n"
        )


def sweep():
    """The tuning sweep recorded in EXPERIMENTS.md: block_rows is free
    (rows axis), gates is fixed by the architecture (k concurrent gates)."""
    out = []
    for rows, cols, gates in [(16, 256, 8), (64, 1024, 32), (1024, 1024, 32)]:
        for block_rows in [8, 32, 128, 512]:
            if block_rows <= rows and rows % block_rows == 0:
                out.append(StepAnalysis(rows, cols, gates, block_rows))
    return out


def main() -> None:
    print("Pallas gate-step kernel — structural performance analysis\n")
    for a in sweep():
        print(a.report())
    print("conclusions (see EXPERIMENTS.md #Perf):")
    print(" * the kernel is memory-bound at every realistic shape: one")
    print("   crossbar cycle touches the whole state for G<=k gates of work;")
    print("   fusing T cycles in the scanned executor keeps state in VMEM")
    print("   across cycles and amortizes the HBM round-trip T times.")
    print(" * block_rows >= 128 fills the MXU rows; utilization is then")
    print("   bounded by G/128 (= k/128) on the output tile.")


if __name__ == "__main__":
    main()
