"""Shared random-program generator for the python test suite (mirrors the
validity rules of the rust Operation model: distinct outputs per cycle,
outputs never alias inputs)."""

from __future__ import annotations

import numpy as np


def random_program(rng: np.random.Generator, c: int, g: int, t: int) -> np.ndarray:
    """A [T, G, 4] random valid program."""
    prog = np.full((t, g, 4), -1, dtype=np.int32)
    for step in range(t):
        outs = rng.choice(c, size=g, replace=False)
        for slot in range(g):
            kind = rng.integers(0, 5)
            o = int(outs[slot])
            if kind == 0:
                continue
            prog[step, slot, 2] = o
            prog[step, slot, 3] = 0
            if kind == 1:
                pass  # init 1
            elif kind == 2:
                prog[step, slot, 3] = 1  # init 0
            elif kind == 3:
                a = int(rng.integers(0, c - 1))
                a = a if a != o else c - 1
                prog[step, slot, 0] = prog[step, slot, 1] = a
            else:
                pool = [x for x in rng.choice(c, size=4, replace=False) if x != o]
                prog[step, slot, 0] = int(pool[0])
                prog[step, slot, 1] = int(pool[1])
    return prog
