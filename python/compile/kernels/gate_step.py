"""L1 Pallas kernel: one crossbar stateful-logic cycle.

One simulated cycle applies up to G concurrent column gates (NOR / NOT /
initialization writes) to every row of the R x C crossbar state at once.

Hardware adaptation (see DESIGN.md #Hardware-Adaptation): instead of
porting the scalar bit-twiddling of a CPU simulator, the cycle is
formulated for the TPU's strengths:

  * input gather  ->  A = state @ sel_a,  B = state @ sel_b   (MXU matmuls
    over one-hot column selectors, [R,C] @ [C,G])
  * gate compute  ->  NOR = (1-A)*(1-B), masked by the per-slot mode
    (mode 1 = write-0 initialization; init-to-1 is NOR of two unused
    inputs)                                                    (VPU)
  * output scatter->  state' = state*(1-outmask) + NOR @ sel_out^T  (MXU)

BlockSpec tiles rows into VMEM-resident blocks; the small [C,G] selector
matrices are replicated per block. ``interpret=True`` everywhere: the CPU
PJRT client cannot execute Mosaic custom-calls, so the kernel lowers to
plain HLO (real-TPU perf is estimated from the VMEM/MXU analysis in
EXPERIMENTS.md #Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_step_kernel(state_ref, sa_ref, sb_ref, so_ref, mode_ref, out_ref):
    """One row-block of the cycle. Shapes:
    state [Rb, C], sa/sb/so [C, G], mode [1, G], out [Rb, C]."""
    state = state_ref[...]
    sa = sa_ref[...]
    sb = sb_ref[...]
    so = so_ref[...]
    mode = mode_ref[...]  # [1, G]; 1.0 = write-0 slot
    # Input gather on the MXU.
    a = jnp.dot(state, sa)  # [Rb, G]
    b = jnp.dot(state, sb)
    # Stateful NOR on the VPU (inputs are 0/1-valued).
    val = (1.0 - a) * (1.0 - b) * (1.0 - mode)
    # Output scatter on the MXU. Columns without a writer keep their value.
    outmask = jnp.sum(so, axis=1)  # [C]
    out_ref[...] = state * (1.0 - outmask)[None, :] + jnp.dot(val, so.T)


def gate_step(state, sel_a, sel_b, sel_out, mode, *, block_rows=None, interpret=True):
    """Apply one simulated cycle.

    Args:
      state:   [R, C] float 0/1 crossbar image.
      sel_a:   [C, G] one-hot InA column selectors (all-zero column = the
               constant-0 input, i.e. a NOT or an init slot).
      sel_b:   [C, G] one-hot InB selectors.
      sel_out: [C, G] one-hot output selectors (all-zero = inactive slot).
      mode:    [1, G] 1.0 where the slot is a write-0 initialization.
      block_rows: VMEM row-block size (defaults to min(R, 128)).
    """
    r, c = state.shape
    g = sel_a.shape[1]
    if block_rows is None:
        block_rows = min(r, 128)
    assert r % block_rows == 0, f"rows {r} not divisible by block {block_rows}"
    grid = (r // block_rows,)
    return pl.pallas_call(
        _gate_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((c, g), lambda i: (0, 0)),
            pl.BlockSpec((c, g), lambda i: (0, 0)),
            pl.BlockSpec((c, g), lambda i: (0, 0)),
            pl.BlockSpec((1, g), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), state.dtype),
        interpret=interpret,
    )(state, sel_a, sel_b, sel_out, mode)


def selectors_from_indices(idx, c, dtype=jnp.float32):
    """Expand a [G, 4] (in_a, in_b, out, mode) int32 step descriptor into the
    kernel's one-hot selector matrices. Index -1 marks an unused line and
    expands to an all-zero selector column (jax one_hot semantics)."""
    sa = jax.nn.one_hot(idx[:, 0], c, dtype=dtype).T  # [C, G]
    sb = jax.nn.one_hot(idx[:, 1], c, dtype=dtype).T
    so = jax.nn.one_hot(idx[:, 2], c, dtype=dtype).T
    mode = idx[:, 3].astype(dtype)[None, :]  # [1, G]
    return sa, sb, so, mode


@functools.partial(jax.jit, static_argnames=("block_rows",))
def step_from_indices(state, idx, *, block_rows=None):
    """One cycle straight from the wire-format step descriptor."""
    sa, sb, so, mode = selectors_from_indices(idx, state.shape[1], state.dtype)
    return gate_step(state, sa, sb, so, mode, block_rows=block_rows)
