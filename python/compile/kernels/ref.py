"""Pure-jnp (and pure-python) oracles for the gate-step kernel.

Two independent references:

* :func:`gate_step_ref` — the same linear-algebra formulation without
  Pallas, for allclose checks of the kernel's lowering.
* :func:`step_semantic` — a direct per-gate semantic interpreter (gather
  all reads first, then scatter writes), matching the rust simulator's
  stateful-logic semantics exactly. This is the ground truth.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gate_step_ref(state, sel_a, sel_b, sel_out, mode):
    """Reference linear-algebra formulation (no pallas)."""
    a = state @ sel_a
    b = state @ sel_b
    val = (1.0 - a) * (1.0 - b) * (1.0 - mode)
    outmask = jnp.sum(sel_out, axis=1)
    return state * (1.0 - outmask)[None, :] + val @ sel_out.T


def step_semantic(state: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Semantic interpreter over a [G, 4] step descriptor.

    All gates of a cycle read the pre-cycle state (they execute in isolated
    sections, so their column sets are disjoint), then writes land.
    """
    out = state.copy()
    reads = state  # pre-cycle snapshot
    for ina, inb, o, mode in np.asarray(idx):
        if o < 0:
            continue
        if mode == 1:
            out[:, o] = 0.0
            continue
        a = reads[:, ina] if ina >= 0 else np.zeros(state.shape[0], state.dtype)
        b = reads[:, inb] if inb >= 0 else np.zeros(state.shape[0], state.dtype)
        out[:, o] = (1.0 - a) * (1.0 - b)
    return out
