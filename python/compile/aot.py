"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``artifacts`` target). Python never runs at request time.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Step-artifact shapes the rust side loads: (rows, cols, gates) with
# gates = k (the maximum concurrent gates of a partitioned operation).
STEP_SHAPES = [
    (16, 256, 8),   # runtime parity tests (n=256, k=8)
    (64, 512, 16),  # mid-size demos
    (64, 1024, 32), # paper scale (n=1024, k=32)
]

# Whole-program executor shapes: (rows, cols, gates, steps).
EXEC_SHAPES = [
    (16, 256, 8, 64),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(path: str, text: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>8} chars  {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    for rows, cols, gates in STEP_SHAPES:
        lowered = jax.jit(model.step).lower(model.state_spec(rows, cols), model.idx_spec(gates))
        emit(os.path.join(args.out_dir, f"step_r{rows}_c{cols}_g{gates}.hlo.txt"), to_hlo_text(lowered))

    for rows, cols, gates, steps in EXEC_SHAPES:
        lowered = jax.jit(model.run_program).lower(
            model.state_spec(rows, cols), model.program_spec(steps, gates)
        )
        emit(
            os.path.join(args.out_dir, f"exec_r{rows}_c{cols}_g{gates}_t{steps}.hlo.txt"),
            to_hlo_text(lowered),
        )


if __name__ == "__main__":
    main()
