"""L2: the crossbar *program executor* as a single JAX computation.

A compiled PIM program (produced by the rust program builders and exported
as wire-format step descriptors) is a [T, G, 4] int32 tensor; the executor
``lax.scan``s the L1 Pallas gate-step kernel over it, so an entire
multiplication (or any other program) lowers to one XLA computation.
Python runs only at build time — the rust runtime loads the lowered HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.gate_step import gate_step, selectors_from_indices


def step(state, idx):
    """One cycle from a [G, 4] step descriptor (tuple-returning for AOT)."""
    sa, sb, so, mode = selectors_from_indices(idx, state.shape[1], state.dtype)
    return (gate_step(state, sa, sb, so, mode),)


def run_program(state, idx_steps):
    """Execute a whole [T, G, 4] program: scan of the pallas step.

    Returns a 1-tuple (AOT lowers with return_tuple=True; the rust side
    unwraps with ``to_tuple1``).
    """

    def body(s, idx):
        sa, sb, so, mode = selectors_from_indices(idx, s.shape[1], s.dtype)
        return gate_step(s, sa, sb, so, mode), None

    final, _ = jax.lax.scan(body, state, idx_steps)
    return (final,)


def state_spec(rows: int, cols: int, dtype=jnp.float32):
    return jax.ShapeDtypeStruct((rows, cols), dtype)


def idx_spec(gates: int):
    return jax.ShapeDtypeStruct((gates, 4), jnp.int32)


def program_spec(steps: int, gates: int):
    return jax.ShapeDtypeStruct((steps, gates, 4), jnp.int32)
