"""Sanity checks of the L1 structural performance model."""

from compile.analysis import StepAnalysis, sweep, VMEM_BYTES


def test_vmem_fits_for_all_sweep_points():
    for a in sweep():
        assert a.vmem_block_bytes < VMEM_BYTES, f"{a} exceeds VMEM"


def test_mxu_utilization_monotone_in_block_rows():
    a8 = StepAnalysis(1024, 1024, 32, 8)
    a128 = StepAnalysis(1024, 1024, 32, 128)
    assert a128.mxu_utilization > a8.mxu_utilization
    # With full 128-row blocks the bound is G/128.
    assert abs(a128.mxu_utilization - 32 / 128) < 1e-9


def test_kernel_is_memory_bound():
    # One cycle reads/writes the whole state for only G gates of matmul
    # work — memory-bound at every paper-scale shape.
    for a in sweep():
        assert a.memory_bound


def test_flop_accounting_scales_linearly_in_rows():
    a = StepAnalysis(64, 1024, 32, 32)
    b = StepAnalysis(128, 1024, 32, 32)
    assert b.mxu_flops == 2 * a.mxu_flops
