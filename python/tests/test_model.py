"""L2 correctness: the scanned program executor vs step-by-step execution,
plus an end-to-end miniature PIM program (a NOR full adder) driven through
the same wire format the rust runtime uses."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.gate_step import step_from_indices
from compile.kernels.ref import step_semantic
from compile.tests_util import random_program  # noqa: F401  (shared helper)

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), t=st.sampled_from([1, 4, 16]))
def test_scan_equals_stepping(seed, t):
    rng = np.random.default_rng(seed)
    r, c, g = 8, 64, 4
    state = rng.integers(0, 2, size=(r, c)).astype(np.float32)
    prog = random_program(rng, c, g, t)

    (scanned,) = model.run_program(jnp.asarray(state), jnp.asarray(prog))

    stepped = jnp.asarray(state)
    for i in range(t):
        stepped = step_from_indices(stepped, jnp.asarray(prog[i]))

    np.testing.assert_array_equal(np.asarray(scanned), np.asarray(stepped))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_scan_equals_semantic(seed):
    rng = np.random.default_rng(seed)
    r, c, g, t = 8, 32, 4, 8
    state = rng.integers(0, 2, size=(r, c)).astype(np.float32)
    prog = random_program(rng, c, g, t)

    (scanned,) = model.run_program(jnp.asarray(state), jnp.asarray(prog))

    sem = state.copy()
    for i in range(t):
        sem = step_semantic(sem, prog[i])
    np.testing.assert_array_equal(np.asarray(scanned), sem)


def full_adder_program(a, b, cin, s, cout, scratch):
    """The same 12-gate NOR/NOT full adder the rust builders emit
    (algorithms/program.rs), in wire format: init cycle + 12 gate cycles."""
    t1, t2, t3, x, u1, u2, u3, nx, v2, w = scratch
    steps = []
    # init scratch + outputs to 1 (one slot per column; G=12 is wide enough).
    init_cols = list(scratch) + [s, cout]
    steps.append([[-1, -1, col, 0] for col in init_cols])
    gates = [
        (a, b, t1), (a, t1, t2), (b, t1, t3), (t2, t3, x),
        (x, cin, u1), (x, u1, u2), (cin, u1, u3), (u2, u3, s),
        (x, x, nx), (t1, nx, v2), (u2, v2, w), (w, w, cout),
    ]
    for ina, inb, out in gates:
        steps.append([[ina, inb, out, 0]] + [[-1, -1, -1, 0]] * 11)
    # pad the init step to G=12
    steps[0] = steps[0] + [[-1, -1, -1, 0]] * (12 - len(steps[0]))
    return np.asarray(steps, dtype=np.int32)


def test_full_adder_end_to_end():
    """All 8 (a, b, cin) combinations in 8 rows at once — the miniature
    version of what the rust coordinator streams at scale."""
    c = 32
    prog = full_adder_program(0, 1, 2, 3, 4, list(range(5, 15)))
    state = np.zeros((8, c), dtype=np.float32)
    for row in range(8):
        state[row, 0] = row & 1
        state[row, 1] = (row >> 1) & 1
        state[row, 2] = (row >> 2) & 1
    (out,) = model.run_program(jnp.asarray(state), jnp.asarray(prog))
    out = np.asarray(out)
    for row in range(8):
        total = (row & 1) + ((row >> 1) & 1) + ((row >> 2) & 1)
        assert out[row, 3] == total % 2, f"sum, row {row}"
        assert out[row, 4] == (total >= 2), f"cout, row {row}"
