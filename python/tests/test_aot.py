"""AOT pipeline smoke tests: the lowering emits loadable HLO text for every
configured shape (the rust loader's parity is covered by
rust/tests/runtime_parity.rs)."""

import os

import jax

from compile import aot, model


def test_step_lowering_emits_hlo_text(tmp_path):
    rows, cols, gates = aot.STEP_SHAPES[0]
    lowered = jax.jit(model.step).lower(model.state_spec(rows, cols), model.idx_spec(gates))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True: the root is a tuple (the rust side unwraps with
    # to_tuple1).
    assert "tuple(" in text or "(f32[" in text


def test_main_emits_all_artifacts(tmp_path, monkeypatch):
    out = tmp_path / "artifacts"
    monkeypatch.setattr("sys.argv", ["aot", "--out-dir", str(out)])
    aot.main()
    for rows, cols, gates in aot.STEP_SHAPES:
        p = out / f"step_r{rows}_c{cols}_g{gates}.hlo.txt"
        assert p.exists() and p.stat().st_size > 1000, p
    for rows, cols, gates, steps in aot.EXEC_SHAPES:
        p = out / f"exec_r{rows}_c{cols}_g{gates}_t{steps}.hlo.txt"
        assert p.exists() and p.stat().st_size > 1000, p


def test_exec_artifact_contains_loop(tmp_path):
    """The scanned executor must lower to a single computation with a while
    loop (one dispatch for the whole program), not per-step calls."""
    rows, cols, gates, steps = aot.EXEC_SHAPES[0]
    lowered = jax.jit(model.run_program).lower(
        model.state_spec(rows, cols), model.program_spec(steps, gates)
    )
    text = aot.to_hlo_text(lowered)
    assert "while" in text, "lax.scan should lower to an HLO while loop"


def test_idempotent_rebuild(tmp_path, monkeypatch):
    out = tmp_path / "artifacts"
    monkeypatch.setattr("sys.argv", ["aot", "--out-dir", str(out)])
    aot.main()
    first = {f: (out / f).read_text() for f in os.listdir(out)}
    aot.main()
    second = {f: (out / f).read_text() for f in os.listdir(out)}
    assert first == second, "AOT lowering must be deterministic"
