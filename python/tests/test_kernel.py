"""L1 correctness: the Pallas gate-step kernel vs two independent oracles.

Hypothesis sweeps shapes, gate counts and step contents; every sample is
checked against (a) the pure-jnp linear-algebra reference and (b) the
semantic per-gate interpreter (the ground truth the rust simulator also
implements).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gate_step import gate_step, selectors_from_indices, step_from_indices
from compile.kernels.ref import gate_step_ref, step_semantic

jax.config.update("jax_platform_name", "cpu")


def random_step(rng: np.random.Generator, c: int, g: int) -> np.ndarray:
    """A random valid step descriptor: distinct outputs, inputs != output,
    a mix of NOR / NOT / init0 / init1 / inactive slots."""
    outs = rng.choice(c, size=g, replace=False)
    idx = np.full((g, 4), -1, dtype=np.int32)
    for slot in range(g):
        kind = rng.integers(0, 5)
        o = int(outs[slot])
        if kind == 0:
            continue  # inactive
        idx[slot, 2] = o
        idx[slot, 3] = 0
        if kind == 1:  # init to 1 (NOR of two unused inputs)
            pass
        elif kind == 2:  # init to 0
            idx[slot, 3] = 1
        elif kind == 3:  # NOT
            a = int(rng.integers(0, c - 1))
            a = a if a != o else c - 1
            idx[slot, 0] = idx[slot, 1] = a
        else:  # NOR
            pool = [x for x in rng.choice(c, size=4, replace=False) if x != o]
            idx[slot, 0] = int(pool[0])
            idx[slot, 1] = int(pool[1])
    return idx


def random_state(rng: np.random.Generator, r: int, c: int) -> np.ndarray:
    return rng.integers(0, 2, size=(r, c)).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(
    r=st.sampled_from([8, 16, 32]),
    c=st.sampled_from([32, 64, 128]),
    g=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**32 - 1),
)
def test_kernel_matches_both_oracles(r, c, g, seed):
    rng = np.random.default_rng(seed)
    state = random_state(rng, r, c)
    idx = random_step(rng, c, g)

    sa, sb, so, mode = selectors_from_indices(jnp.asarray(idx), c)
    out_kernel = np.asarray(gate_step(jnp.asarray(state), sa, sb, so, mode))
    out_ref = np.asarray(gate_step_ref(jnp.asarray(state), sa, sb, so, mode))
    out_sem = step_semantic(state, idx)

    np.testing.assert_allclose(out_kernel, out_ref, atol=0, rtol=0)
    np.testing.assert_allclose(out_kernel, out_sem, atol=0, rtol=0)
    # Outputs stay strictly binary.
    assert set(np.unique(out_kernel)).issubset({0.0, 1.0})


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), block=st.sampled_from([8, 16, 32]))
def test_row_blocking_invariant(seed, block):
    """The BlockSpec row tiling must not change results."""
    rng = np.random.default_rng(seed)
    state = random_state(rng, 32, 64)
    idx = random_step(rng, 64, 4)
    sa, sb, so, mode = selectors_from_indices(jnp.asarray(idx), 64)
    full = gate_step(jnp.asarray(state), sa, sb, so, mode, block_rows=32)
    tiled = gate_step(jnp.asarray(state), sa, sb, so, mode, block_rows=block)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(tiled))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    """0/1 values are exact in bf16 too; the kernel must stay binary."""
    rng = np.random.default_rng(7)
    state = jnp.asarray(random_state(rng, 16, 32), dtype=dtype)
    idx = jnp.asarray(random_step(rng, 32, 4))
    sa, sb, so, mode = selectors_from_indices(idx, 32, dtype=dtype)
    out = np.asarray(gate_step(state, sa, sb, so, mode)).astype(np.float32)
    sem = step_semantic(np.asarray(state, dtype=np.float32), np.asarray(idx))
    np.testing.assert_allclose(out, sem, atol=0, rtol=0)


def test_nor_truth_table():
    """Explicit 4-row truth table through the kernel."""
    state = jnp.asarray([[0, 0, 1], [0, 1, 1], [1, 0, 1], [1, 1, 1]], dtype=jnp.float32)
    idx = jnp.asarray([[0, 1, 2, 0]], dtype=jnp.int32)  # col2 = NOR(col0, col1)
    out = np.asarray(step_from_indices(state, idx))
    np.testing.assert_array_equal(out[:, 2], [1, 0, 0, 0])


def test_not_and_inits():
    state = jnp.zeros((4, 8), dtype=jnp.float32).at[:, 0].set([0, 1, 0, 1])
    idx = jnp.asarray(
        [
            [0, 0, 1, 0],    # col1 = NOT(col0)
            [-1, -1, 2, 0],  # col2 = init 1
            [-1, -1, 3, 1],  # col3 = init 0
            [-1, -1, -1, 0], # inactive
        ],
        dtype=jnp.int32,
    )
    out = np.asarray(step_from_indices(state, idx))
    np.testing.assert_array_equal(out[:, 1], [1, 0, 1, 0])
    np.testing.assert_array_equal(out[:, 2], [1, 1, 1, 1])
    np.testing.assert_array_equal(out[:, 3], [0, 0, 0, 0])


def test_untouched_columns_preserved():
    rng = np.random.default_rng(3)
    state = random_state(rng, 8, 16)
    idx = np.asarray([[0, 1, 5, 0]], dtype=np.int32)
    out = np.asarray(step_from_indices(jnp.asarray(state), jnp.asarray(idx)))
    keep = [c for c in range(16) if c != 5]
    np.testing.assert_array_equal(out[:, keep], state[:, keep])
