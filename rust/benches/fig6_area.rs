//! Figure 6(c) + Section 5.3 — algorithmic area (memristor footprint) and
//! physical overhead per model (experiments E8, E12 summary).

use partition_pim::bench_support::section;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::figures;

fn main() {
    section("Figure 6(c): algorithmic area for 32-bit multiplication (paper: ~1.4x)");
    println!("{:<11} {:>14} {:>9}", "model", "memristors/row", "ratio");
    for r in figures::figure6().expect("figure6") {
        println!("{:<11} {:>14} {:>8.2}x", r.model.name(), r.stats.footprint_cols, r.area_ratio);
    }

    let geom = Geometry::paper(64).expect("paper geometry");
    section("physical overhead");
    println!("isolation transistors: {:.2}% of row cells (paper cites ~3% [8])", 100.0 * figures::transistor_overhead(&geom));
    for r in figures::periphery_table(&geom) {
        println!(
            "{:<22} CMOS gates {:>9}  analog muxes {:>7}  extra logic {:>6}",
            r.name, r.area.cmos_gates, r.area.analog_muxes, r.area.extra_logic_gates
        );
    }
}
