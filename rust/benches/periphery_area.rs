//! Experiment E12: half-gate periphery vs the naive Ω(k²) decoder stack
//! (Figure 3) across partition counts, plus the functional decoder's
//! wall-clock cost.

use partition_pim::bench_support::{bench, section};
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::operation::Direction;
use partition_pim::periphery::area::{naive_unlimited_area, periphery_area};
use partition_pim::periphery::{opcode_gen, range_gen};

fn main() {
    section("periphery CMOS gates vs k (n = 1024)");
    println!("{:>4} {:>10} {:>11} {:>10} {:>10} {:>13}", "k", "baseline", "half-gates", "standard", "minimal", "naive stack");
    for k in [2usize, 4, 8, 16, 32] {
        let geom = Geometry::new(1024, k, 1).expect("geometry");
        let b = periphery_area(ModelKind::Baseline, &geom).cmos_gates;
        let u = periphery_area(ModelKind::Unlimited, &geom).cmos_gates;
        let s = periphery_area(ModelKind::Standard, &geom).total_gates();
        let m = periphery_area(ModelKind::Minimal, &geom).total_gates();
        let naive = naive_unlimited_area(&geom).cmos_gates;
        println!("{k:>4} {b:>10} {u:>11} {s:>10} {m:>10} {naive:>13}");
    }
    println!("\n(half-gates stays below the baseline — Section 2.2; the naive stack explodes quadratically)");

    section("functional generator wall-clock (k = 32)");
    let enables = vec![true; 32];
    let selects = vec![true; 31];
    bench("opcode_gen/standard", || {
        let ops = opcode_gen::generate(&enables, &selects, Direction::InputsLeft).expect("generate");
        assert_eq!(ops.len(), 32);
    });
    let params = range_gen::RangeParams { p_start: 0, p_end: 30, t: 2, distance: 1, dir: Direction::InputsLeft };
    bench("range_gen/minimal", || {
        let e = range_gen::expand(&params, 32).expect("expand");
        assert_eq!(e.in_mask.len(), 32);
    });
}
