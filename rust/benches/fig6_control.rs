//! Figure 6(b) + Sections 2.3/3.3/4.3 — control-message lengths, lower
//! bounds, per-program control traffic, and codec wall-clock throughput
//! (experiments E2-E5, E7).

use partition_pim::bench_support::{bench, section, throughput};
use partition_pim::coordinator::worker::{compile_workload, workload_geometry, WorkloadKind};
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::figures;
use partition_pim::isa::encode::{decode, encode, message_bits};
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::operation::{GateOp, Operation};
use partition_pim::periphery;

fn main() {
    let geom = Geometry::paper(64).expect("paper geometry");

    section("Figure 6(b): message formats vs lower bounds (paper: 30/607/79/36 bits)");
    println!("{:<11} {:>12} {:>13} {:>10}", "model", "format bits", "lower bound", "overhead");
    for r in figures::control_table(&geom) {
        println!(
            "{:<11} {:>12} {:>13} {:>9.1}x",
            r.model.name(),
            r.format_bits,
            r.lower_bound_bits,
            r.format_bits as f64 / message_bits(ModelKind::Baseline, &geom) as f64
        );
    }

    section("total control traffic for one 32-bit multiplication");
    for model in ModelKind::ALL {
        let g = workload_geometry(WorkloadKind::Mul32, model, 1).expect("geometry");
        let (prog, _) = compile_workload(WorkloadKind::Mul32, model, g).expect("compile");
        println!(
            "{:<11} {:>10} bits over {:>5} cycles",
            model.name(),
            prog.control_bits(model),
            prog.stats().cycles
        );
    }

    section("codec wall-clock (encode + decode + periphery reconstruction)");
    let par_op = Operation::Gates((0..geom.k).map(|p| GateOp::nor(geom.col(p, 0), geom.col(p, 1), geom.col(p, 3))).collect());
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let res = bench(&format!("roundtrip/{}/parallel-op", model.name()), || {
            let bits = encode(model, &par_op, &geom).expect("encode");
            let msg = decode(model, &bits, &geom).expect("decode");
            let op = periphery::reconstruct(&msg, &geom).expect("reconstruct");
            assert_eq!(op.gate_count(), geom.k);
        });
        throughput(&res, 1.0, "msg");
    }
}
