//! Experiment E10: in-memory sorting speedup from partitions (the paper's
//! intro cites 14x with 16 partitions [1]).

use partition_pim::algorithms::sort::{build_sorter_partitioned, build_sorter_serial};
use partition_pim::backend::ExecPipeline;
use partition_pim::bench_support::{bench, section, throughput};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::figures;

fn main() {
    section("sorting cycles: serial vs partitioned bitonic network");
    println!("{:>6} {:>7} {:>14} {:>19} {:>9}", "elems", "w bits", "serial cycles", "partitioned cycles", "speedup");
    for r in figures::sort_table(6).expect("sort table") {
        println!("{:>6} {:>7} {:>14} {:>19} {:>8.2}x", r.elems, r.w_bits, r.serial_cycles, r.partitioned_cycles, r.speedup);
    }

    section("wall-clock: simulator running a 16-element sort over 64 rows");
    let geom = Geometry::new(512, 16, 64).expect("geometry");
    let par = build_sorter_partitioned(geom, 6).expect("partitioned sorter");
    let mut xb = Crossbar::new(geom, GateSet::NotNor);
    xb.state.fill_random(3);
    let mut pipe = ExecPipeline::direct(&mut xb);
    let res = bench("sort16x6/partitioned/64rows", || {
        par.program.execute(&mut pipe).expect("run");
    });
    throughput(&res, 64.0 * 16.0, "elements");

    let sgeom = Geometry::new(1024, 1, 64).expect("geometry");
    let ser = build_sorter_serial(sgeom, 16, 6).expect("serial sorter");
    let mut sxb = Crossbar::new(sgeom, GateSet::NotNor);
    sxb.state.fill_random(3);
    let mut spipe = ExecPipeline::direct(&mut sxb);
    let res = bench("sort16x6/serial/64rows", || {
        ser.program.execute(&mut spipe).expect("run");
    });
    throughput(&res, 64.0 * 16.0, "elements");
}
