//! Experiment E19: the SHA-3 (HashPIM) workload — Keccak-f[1600] rounds
//! per second through the serving worker, and the cycles-per-round latency
//! held against the published 3,494-cycle HashPIM budget.
//!
//! Three sections:
//!  1. Round budget: the emitted per-step cycle/gate table vs the published
//!     HashPIM table (the same numbers `tests/sha3_cycles.rs` asserts).
//!  2. Worker throughput: full 24-round permutations per wall second on the
//!     decode-once replay path, across batch (row) counts.
//!  3. Replay-mode cost: decoded-cache vs full wire re-decode wall time for
//!     the same batch.
//!
//! Emits `BENCH_sha3.json` so CI can accumulate the workload's trajectory
//! across PRs (companion to `BENCH_coordinator.json`, `BENCH_fleet.json`
//! and `BENCH_wear.json`).

use partition_pim::algorithms::sha3;
use partition_pim::backend::ReplayMode;
use partition_pim::bench_support::section;
use partition_pim::coordinator::worker::Worker;
use partition_pim::coordinator::{workload_geometry, WorkloadKind};
use partition_pim::isa::models::ModelKind;
use std::time::Instant;

const MODEL: ModelKind = ModelKind::Minimal;
const BATCHES: usize = 8;

fn xorshift(seed: &mut u64) -> u64 {
    *seed ^= *seed << 13;
    *seed ^= *seed >> 7;
    *seed ^= *seed << 17;
    *seed
}

fn states(rows: usize, seed: &mut u64) -> Vec<[u64; 25]> {
    (0..rows)
        .map(|_| {
            let mut st = [0u64; 25];
            for lane in st.iter_mut() {
                *lane = xorshift(seed);
            }
            st
        })
        .collect()
}

/// Permutations per wall second over `BATCHES` verified batches of `rows`
/// states; returns (rounds/s, cycles per round as metered).
fn worker_throughput(rows: usize, mode: ReplayMode) -> (f64, f64) {
    let geom = workload_geometry(WorkloadKind::Sha3, MODEL, rows).expect("geometry");
    let mut worker = Worker::new(WorkloadKind::Sha3, MODEL, geom).expect("worker");
    worker.set_replay(mode, 1);
    let mut seed = 0x6a09_e667_f3bc_c908u64;
    let mut cycles_per_batch = 0u64;
    let t0 = Instant::now();
    for batch in 0..BATCHES {
        let input = states(rows, &mut seed);
        let (out, metrics) = worker.run_sha3_batch(&input).expect("batch");
        cycles_per_batch = metrics.cycles;
        if batch == 0 {
            for (r, st) in input.iter().enumerate() {
                let mut want = *st;
                sha3::keccak_f_sw(&mut want);
                assert_eq!(out[r], want, "row {r} diverged from the software oracle");
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let permutations = (BATCHES * rows) as f64;
    let rounds_per_sec = permutations * sha3::ROUNDS as f64 / wall;
    let cycles_per_round = cycles_per_batch as f64 / sha3::ROUNDS as f64;
    (rounds_per_sec, cycles_per_round)
}

fn main() {
    let geom = workload_geometry(WorkloadKind::Sha3, MODEL, 4).expect("geometry");
    let unit = sha3::build_keccak_f(geom).expect("build");
    let round = unit.round_stats.total();

    section("round budget: emitted per-step schedule vs the published HashPIM table");
    println!("      {:<7} {:>8} {:>8} {:>14} {:>16}", "step", "cycles", "gates", "published cyc", "published gates");
    for ((name, s), (_, pc, pg)) in unit.round_stats.steps().into_iter().zip(sha3::PUBLISHED_STEP_TABLE) {
        println!("      {:<7} {:>8} {:>8} {:>14} {:>16}", name, s.cycles, s.gates, pc, pg);
    }
    println!(
        "      {:<7} {:>8} {:>8} {:>14} {:>16}",
        "round", round.cycles, round.gates, sha3::PUBLISHED_ROUND_CYCLES, sha3::PUBLISHED_ROUND_GATES
    );
    assert!(round.cycles <= sha3::PUBLISHED_ROUND_CYCLES, "round latency must stay within the published budget");
    let budget_ratio = round.cycles as f64 / sha3::PUBLISHED_ROUND_CYCLES as f64;

    section(&format!("worker throughput: {BATCHES} verified batches per row count, decoded replay, {} model", MODEL.name()));
    let mut rows_results = Vec::new();
    for rows in [4usize, 16, 64] {
        let (rps, cpr) = worker_throughput(rows, ReplayMode::Decoded);
        println!("      {rows:>3} rows: {rps:>10.0} rounds/s   ({cpr:.0} metered cycles/round)");
        rows_results.push((rows, rps, cpr));
    }

    section("replay-mode cost: decoded cache vs full wire re-decode, 16 rows");
    let (dec_rps, _) = worker_throughput(16, ReplayMode::Decoded);
    let (wire_rps, _) = worker_throughput(16, ReplayMode::Wire);
    println!("      decoded: {dec_rps:>10.0} rounds/s");
    println!("      wire   : {wire_rps:>10.0} rounds/s   (decode-once speedup {:.2}x)", dec_rps / wire_rps);

    let rows_json: Vec<String> = rows_results
        .iter()
        .map(|(rows, rps, cpr)| format!("{{\"rows\": {rows}, \"rounds_per_sec\": {rps:.1}, \"metered_cycles_per_round\": {cpr:.1}}}"))
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"sha3\",\n  \"config\": {{\"model\": \"{}\", \"batches\": {BATCHES}, \"rounds\": {}}},\n  \
         \"round_budget\": {{\"cycles\": {}, \"gates\": {}, \"published_cycles\": {}, \"published_gates\": {}, \
         \"budget_ratio\": {budget_ratio:.3}}},\n  \
         \"throughput\": [{}],\n  \
         \"replay\": {{\"decoded_rounds_per_sec\": {dec_rps:.1}, \"wire_rounds_per_sec\": {wire_rps:.1}, \
         \"decode_once_speedup\": {:.2}}}\n}}\n",
        MODEL.name(),
        sha3::ROUNDS,
        round.cycles,
        round.gates,
        sha3::PUBLISHED_ROUND_CYCLES,
        sha3::PUBLISHED_ROUND_GATES,
        rows_json.join(", "),
        dec_rps / wire_rps
    );
    match std::fs::write("BENCH_sha3.json", json) {
        Ok(()) => println!("\nwrote BENCH_sha3.json"),
        Err(e) => println!("\nWARNING: could not write BENCH_sha3.json: {e}"),
    }
}
