//! Ablation: MultPIM broadcast strategies (DESIGN.md §7) — the
//! minimal-legal double-NOT tree vs the parity single-NOT tree, and what
//! each costs under every model after legalization/packing.

use partition_pim::algorithms::multpim::{build_multpim, MultPimVariant};
use partition_pim::bench_support::section;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::figures;
use partition_pim::isa::lower::LegalizeConfig;
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::schedule::pack_program;

fn main() {
    let geom = Geometry::paper(1).expect("paper geometry");

    section("broadcast variants (32-bit multiplication, n=1024, k=32)");
    for r in figures::broadcast_ablation(geom).expect("ablation") {
        println!("{:<36} {:>6} cycles {:>7} gates", r.name, r.cycles, r.gates);
    }

    section("variant x model matrix (cycles after legalize/pack)");
    println!("{:<10} {:>12} {:>12}", "model", "plain", "fast");
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let mut cells = Vec::new();
        for variant in [MultPimVariant::Plain, MultPimVariant::Fast] {
            let m = build_multpim(geom, variant).expect("build");
            let cycles = if m.program.check_model(model).is_ok() {
                let (packed, _) = pack_program(&m.program.ops, model, &geom, GateSet::NotNor);
                packed.len()
            } else {
                match m.program.legalize(model, &LegalizeConfig::default()) {
                    Ok((legal, _)) => legal.ops.len(),
                    Err(_) => 0, // not legalizable without scratch
                }
            };
            cells.push(cycles);
        }
        println!("{:<10} {:>12} {:>12}", model.name(), cells[0], cells[1]);
    }
    println!("\n(the fast parity tree wins under unlimited/standard; its aperiodic");
    println!(" subset cycles make it lose to the plain tree under minimal — the");
    println!(" reason the minimal-model worker compiles the plain variant)");
}
