//! L3 hot-path throughput: row-gates/second of the bit-packed simulator
//! (the §Perf target: ≥ 1e8 row-gates/s), across geometries and paths,
//! plus the replay fast path (experiment E17): decode-once cached replay
//! vs wire re-decode, and word-range parallel scaling at 1/2/4 threads.
//!
//! Emits `BENCH_sim_throughput.json` so CI can accumulate the perf
//! trajectory across PRs.

use partition_pim::backend::{ExecPipeline, PimBackend, ReplayMode};
use partition_pim::bench_support::{bench, section, throughput};
use partition_pim::coordinator::worker::{compile_workload, workload_geometry, WorkloadKind};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::operation::{GateOp, Operation};

const TARGET_ROW_GATES_PER_SEC: f64 = 1.0e8;

fn parallel_op(geom: &Geometry) -> Operation {
    Operation::Gates((0..geom.k).map(|p| GateOp::nor(geom.col(p, 0), geom.col(p, 1), geom.col(p, 3))).collect())
}

struct ExecuteRow {
    n: usize,
    k: usize,
    rows: usize,
    row_gates_per_sec: f64,
}

struct ReplayRow {
    wire_row_gates_per_sec: f64,
    decoded_row_gates_per_sec: f64,
    decoded_speedup: f64,
}

struct ScalingRow {
    threads: usize,
    row_gates_per_sec: f64,
    speedup: f64,
}

fn write_json(execute: &[ExecuteRow], replay: &ReplayRow, scaling: &[ScalingRow]) {
    let peak = execute
        .iter()
        .map(|r| r.row_gates_per_sec)
        .chain(scaling.iter().map(|r| r.row_gates_per_sec))
        .fold(0.0f64, f64::max);
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"sim_throughput\",\n");
    s.push_str(&format!("  \"target_row_gates_per_sec\": {TARGET_ROW_GATES_PER_SEC:.1},\n"));
    s.push_str(&format!("  \"peak_row_gates_per_sec\": {peak:.1},\n"));
    s.push_str("  \"execute\": [\n");
    for (i, r) in execute.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"n\": {}, \"k\": {}, \"rows\": {}, \"row_gates_per_sec\": {:.1}}}{}\n",
            r.n,
            r.k,
            r.rows,
            r.row_gates_per_sec,
            if i + 1 < execute.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!(
        "  \"replay\": {{\"workload\": \"mul32\", \"model\": \"minimal\", \"rows\": 64, \"wire_row_gates_per_sec\": {:.1}, \"decoded_row_gates_per_sec\": {:.1}, \"decoded_speedup\": {:.3}}},\n",
        replay.wire_row_gates_per_sec, replay.decoded_row_gates_per_sec, replay.decoded_speedup
    ));
    s.push_str("  \"word_range_scaling\": [\n");
    for (i, r) in scaling.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"threads\": {}, \"row_gates_per_sec\": {:.1}, \"speedup\": {:.3}}}{}\n",
            r.threads,
            r.row_gates_per_sec,
            r.speedup,
            if i + 1 < scaling.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    match std::fs::write("BENCH_sim_throughput.json", s) {
        Ok(()) => println!("\nwrote BENCH_sim_throughput.json"),
        Err(e) => println!("\nWARNING: could not write BENCH_sim_throughput.json: {e}"),
    }
}

fn main() {
    let mut execute_rows: Vec<ExecuteRow> = Vec::new();
    section("bit-packed simulator: parallel operation (k gates x rows)");
    for (n, k, rows) in [(1024usize, 32usize, 64usize), (1024, 32, 1024), (1024, 32, 16384), (256, 8, 1024)] {
        let geom = Geometry::new(n, k, rows).expect("geometry");
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(7);
        let op = parallel_op(&geom);
        let res = bench(&format!("execute/n{n}k{k}r{rows}"), || {
            xb.execute(&op).expect("execute");
        });
        throughput(&res, (geom.k * rows) as f64, "row-gates");
        execute_rows.push(ExecuteRow {
            n,
            k,
            rows,
            row_gates_per_sec: (geom.k * rows) as f64 / res.mean.as_secs_f64(),
        });
    }

    section("message path: decode + periphery + execute (minimal model)");
    for rows in [64usize, 1024] {
        let geom = Geometry::new(1024, 32, rows).expect("geometry");
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(7);
        let op = parallel_op(&geom);
        // Pre-encode once; each iteration replays the decode + execute side
        // (forced to the wire path so the periphery decoder stays in the loop).
        let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
        pipe.set_replay_mode(ReplayMode::Wire);
        let prepared = pipe.prepare(std::slice::from_ref(&op)).expect("prepare");
        let res = bench(&format!("message/n1024k32r{rows}"), || {
            pipe.run_prepared(&prepared).expect("execute");
        });
        throughput(&res, (geom.k * rows) as f64, "row-gates");
    }

    section("replay fast path: mul32 workload, wire vs decode-once cache (minimal, 64 rows)");
    let replay_row = {
        let geom = workload_geometry(WorkloadKind::Mul32, ModelKind::Minimal, 64).expect("geometry");
        let (prog, _) = compile_workload(WorkloadKind::Mul32, ModelKind::Minimal, geom).expect("compile");
        let row_gates = (prog.stats().gates * geom.rows) as f64;
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(7);
        let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
        let prepared = prog.prepare(&mut pipe).expect("prepare");
        pipe.set_replay_mode(ReplayMode::Wire);
        let wire = bench("replay/mul32/minimal/wire", || {
            pipe.run_prepared(&prepared).expect("run");
        });
        throughput(&wire, row_gates, "row-gates");
        pipe.set_replay_mode(ReplayMode::Decoded);
        let decoded = bench("replay/mul32/minimal/decoded", || {
            pipe.run_prepared(&prepared).expect("run");
        });
        throughput(&decoded, row_gates, "row-gates");
        let decoded_speedup = wire.mean_ns() / decoded.mean_ns();
        println!("      -> decoded replay speedup: {decoded_speedup:.2}x");
        ReplayRow {
            wire_row_gates_per_sec: row_gates / wire.mean.as_secs_f64(),
            decoded_row_gates_per_sec: row_gates / decoded.mean.as_secs_f64(),
            decoded_speedup,
        }
    };

    section("word-range scaling: decoded replay across parallel word ranges (minimal, 16384 rows)");
    let scaling_rows = {
        let geom = workload_geometry(WorkloadKind::Mul32, ModelKind::Minimal, 16384).expect("geometry");
        let (prog, _) = compile_workload(WorkloadKind::Mul32, ModelKind::Minimal, geom).expect("compile");
        let row_gates = (prog.stats().gates * geom.rows) as f64;
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(7);
        let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
        let prepared = prog.prepare(&mut pipe).expect("prepare");
        let mut rows: Vec<ScalingRow> = Vec::new();
        let mut base_ns = 0.0f64;
        for threads in [1usize, 2, 4] {
            pipe.set_replay_threads(threads);
            let res = bench(&format!("replay/mul32/minimal/16384rows/t{threads}"), || {
                pipe.run_prepared(&prepared).expect("run");
            });
            throughput(&res, row_gates, "row-gates");
            if threads == 1 {
                base_ns = res.mean_ns();
            }
            let speedup = base_ns / res.mean_ns();
            println!("      -> scaling vs 1 thread: {speedup:.2}x");
            rows.push(ScalingRow {
                threads,
                row_gates_per_sec: row_gates / res.mean.as_secs_f64(),
                speedup,
            });
        }
        rows
    };

    section("initialization writes");
    let geom = Geometry::new(1024, 32, 1024).expect("geometry");
    let mut xb = Crossbar::new(geom, GateSet::NotNor);
    let cols: Vec<usize> = (0..geom.k).flat_map(|p| (10..20).map(move |i| geom.col(p, i))).collect();
    let op = Operation::init1(cols.clone());
    let res = bench("init/320cols/1024rows", || {
        xb.execute(&op).expect("init");
    });
    throughput(&res, (cols.len() * geom.rows) as f64, "cell-writes");

    write_json(&execute_rows, &replay_row, &scaling_rows);
}
