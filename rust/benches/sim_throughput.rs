//! L3 hot-path throughput: row-gates/second of the bit-packed simulator
//! (the §Perf target: ≥ 1e8 row-gates/s), across geometries and paths.

use partition_pim::backend::{ExecPipeline, PimBackend};
use partition_pim::bench_support::{bench, section, throughput};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::operation::{GateOp, Operation};

fn parallel_op(geom: &Geometry) -> Operation {
    Operation::Gates((0..geom.k).map(|p| GateOp::nor(geom.col(p, 0), geom.col(p, 1), geom.col(p, 3))).collect())
}

fn main() {
    section("bit-packed simulator: parallel operation (k gates x rows)");
    for (n, k, rows) in [(1024usize, 32usize, 64usize), (1024, 32, 1024), (1024, 32, 16384), (256, 8, 1024)] {
        let geom = Geometry::new(n, k, rows).expect("geometry");
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(7);
        let op = parallel_op(&geom);
        let res = bench(&format!("execute/n{n}k{k}r{rows}"), || {
            xb.execute(&op).expect("execute");
        });
        throughput(&res, (geom.k * rows) as f64, "row-gates");
    }

    section("message path: decode + periphery + execute (minimal model)");
    for rows in [64usize, 1024] {
        let geom = Geometry::new(1024, 32, rows).expect("geometry");
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(7);
        let op = parallel_op(&geom);
        // Pre-encode once; each iteration replays the decode + execute side.
        let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
        let prepared = pipe.prepare(std::slice::from_ref(&op)).expect("prepare");
        let res = bench(&format!("message/n1024k32r{rows}"), || {
            pipe.run_prepared(&prepared).expect("execute");
        });
        throughput(&res, (geom.k * rows) as f64, "row-gates");
    }

    section("initialization writes");
    let geom = Geometry::new(1024, 32, 1024).expect("geometry");
    let mut xb = Crossbar::new(geom, GateSet::NotNor);
    let cols: Vec<usize> = (0..geom.k).flat_map(|p| (10..20).map(move |i| geom.col(p, i))).collect();
    let op = Operation::init1(cols.clone());
    let res = bench("init/320cols/1024rows", || {
        xb.execute(&op).expect("init");
    });
    throughput(&res, (cols.len() * geom.rows) as f64, "cell-writes");
}
