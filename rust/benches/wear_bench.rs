//! Experiment E18: wear-aware serving — how far cold-row leveling stretches
//! the endurance horizon, what it costs in throughput, and what stuck-at
//! quarantine + remap does to a live trace.
//!
//! Three sections:
//!  1. Wear spread: the same sequential small-job trace with leveling off
//!     (historical front-packing) vs on; reports peak row wear and the wear
//!     Gini for both, and the horizon extension factor (the ratio of peak
//!     wears — the factor by which time-to-first-failure stretches under a
//!     fixed per-row endurance budget).
//!  2. Throughput cost: pipelined serving rate with leveling on vs off (the
//!     placement sort is the only extra work).
//!  3. Remap: a stuck-at fault struck mid-trace; every job must still
//!     complete, and the quarantine/remap counters are reported.
//!
//! Emits `BENCH_wear.json` so CI can accumulate the reliability-tier
//! trajectory across PRs (companion to `BENCH_coordinator.json` and
//! `BENCH_fleet.json`).

use partition_pim::bench_support::section;
use partition_pim::coordinator::{PimService, ServiceConfig, WorkloadKind};
use partition_pim::isa::models::ModelKind;
use std::time::Instant;

const ROWS: usize = 32;
const SPREAD_JOBS: usize = 64;
const SPREAD_SPAN: usize = 4;
const THROUGHPUT_JOBS: usize = 40;
const THROUGHPUT_LEN: usize = 96;
const REMAP_JOBS: usize = 24;
const REMAP_LEN: usize = 24;

fn service(n_crossbars: usize, wear_leveling: bool) -> PimService {
    PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars,
        rows: ROWS,
        wear_leveling,
        ..Default::default()
    })
    .expect("service")
}

fn xorshift(seed: &mut u64) -> u64 {
    *seed ^= *seed << 13;
    *seed ^= *seed >> 7;
    *seed ^= *seed << 17;
    *seed
}

/// Sequential small-span trace on one crossbar; returns (max row wear, gini).
fn wear_spread(leveling: bool) -> (u64, f64) {
    let svc = service(1, leveling);
    let a = vec![0xdead_beefu64; SPREAD_SPAN];
    let b = vec![0x0bad_cafeu64; SPREAD_SPAN];
    for _ in 0..SPREAD_JOBS {
        svc.submit(&a, &b).expect("submit").wait().expect("job");
    }
    let wear = svc.wear();
    svc.shutdown();
    (wear.max_wear(), wear.gini())
}

/// Pipelined trace; returns elements per wall second.
fn throughput(leveling: bool) -> f64 {
    let svc = service(2, leveling);
    let mut seed = 0x9e3779b97f4a7c15u64;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..THROUGHPUT_JOBS {
        let a: Vec<u64> = (0..THROUGHPUT_LEN).map(|_| xorshift(&mut seed) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..THROUGHPUT_LEN).map(|_| xorshift(&mut seed) & 0xffff_ffff).collect();
        handles.push(svc.submit(&a, &b).expect("submit"));
    }
    for h in handles {
        h.wait().expect("job");
    }
    let wall = t0.elapsed().as_secs_f64();
    svc.shutdown();
    (THROUGHPUT_JOBS * THROUGHPUT_LEN) as f64 / wall
}

fn main() {
    section(&format!(
        "wear spread: {SPREAD_JOBS} sequential span-{SPREAD_SPAN} jobs on {ROWS} rows, front-packed vs wear-leveled placement"
    ));
    let (packed_max, packed_gini) = wear_spread(false);
    let (leveled_max, leveled_gini) = wear_spread(true);
    let horizon_factor = packed_max as f64 / leveled_max as f64;
    assert!(
        horizon_factor > 1.0,
        "leveling must lower peak row wear (packed {packed_max}, leveled {leveled_max})"
    );
    println!("      front-packed: max row wear {packed_max}, gini {packed_gini:.3}");
    println!("      leveled     : max row wear {leveled_max}, gini {leveled_gini:.3}");
    println!("      horizon extension factor: {horizon_factor:.2}x (TTFF stretch at any fixed endurance budget)");

    section(&format!("throughput cost of leveling: {THROUGHPUT_JOBS} pipelined jobs x {THROUGHPUT_LEN} elements, 2 crossbars"));
    let packed_eps = throughput(false);
    let leveled_eps = throughput(true);
    let cost_pct = 100.0 * (1.0 - leveled_eps / packed_eps);
    println!("      front-packed: {packed_eps:.0} elements/s");
    println!("      leveled     : {leveled_eps:.0} elements/s  (leveling cost {cost_pct:+.1}%)");

    section(&format!("stuck-at remap: fault struck mid-trace, {REMAP_JOBS} jobs x {REMAP_LEN} elements must all complete"));
    let svc = service(1, true);
    let mut seed = 0x2545_f491_4f6c_dd1du64;
    let mut handles = Vec::new();
    for j in 0..REMAP_JOBS {
        let a: Vec<u64> = (0..REMAP_LEN).map(|_| xorshift(&mut seed) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..REMAP_LEN).map(|_| xorshift(&mut seed) & 0xffff_ffff).collect();
        let handle = svc.submit(&a, &b);
        handles.push((a, b, handle));
        if j == REMAP_JOBS / 2 {
            svc.inject_stuck(3, 0, true).expect("inject");
        }
    }
    let mut completed = 0usize;
    for (a, b, handle) in handles {
        let res = handle.expect("submit").wait().expect("job must survive the stuck fault");
        let vals = res.try_scalars().expect("scalar job");
        for i in 0..a.len() {
            assert_eq!(vals[i], a[i] * b[i], "corrupted value leaked past quarantine");
        }
        completed += 1;
    }
    let stats = svc.shutdown();
    println!(
        "      completed {completed}/{REMAP_JOBS} jobs   quarantined rows {}   remapped segments {}",
        stats.wear.quarantined_rows, stats.remapped_segments
    );

    let json = format!(
        "{{\n  \"bench\": \"wear\",\n  \"config\": {{\"rows\": {ROWS}, \"spread_jobs\": {SPREAD_JOBS}, \"spread_span\": {SPREAD_SPAN}, \
         \"throughput_jobs\": {THROUGHPUT_JOBS}, \"throughput_len\": {THROUGHPUT_LEN}, \"remap_jobs\": {REMAP_JOBS}}},\n  \
         \"leveling\": {{\"packed_max_row_wear\": {packed_max}, \"leveled_max_row_wear\": {leveled_max}, \"packed_gini\": {packed_gini:.3}, \
         \"leveled_gini\": {leveled_gini:.3}, \"horizon_extension_factor\": {horizon_factor:.2}}},\n  \
         \"throughput\": {{\"packed_elements_per_sec\": {packed_eps:.1}, \"leveled_elements_per_sec\": {leveled_eps:.1}, \
         \"leveling_cost_pct\": {cost_pct:.1}}},\n  \
         \"remap\": {{\"jobs\": {REMAP_JOBS}, \"completed\": {completed}, \"quarantined_rows\": {}, \"remapped_segments\": {}}}\n}}\n",
        stats.wear.quarantined_rows, stats.remapped_segments
    );
    match std::fs::write("BENCH_wear.json", json) {
        Ok(()) => println!("\nwrote BENCH_wear.json"),
        Err(e) => println!("\nWARNING: could not write BENCH_wear.json: {e}"),
    }
}
