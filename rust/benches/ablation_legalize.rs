//! Ablation: legalization strategies (DESIGN.md §7) — what each model's
//! restrictions cost when rewriting the standard-legal Fast multiplier,
//! and the cost of the split-input copy rewrite.

use partition_pim::algorithms::multpim::{build_multpim, MultPimVariant};
use partition_pim::bench_support::{bench, section};
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::lower::{legalize_program, LegalizeConfig, LegalizeStats};
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::operation::{GateOp, Operation};
use partition_pim::isa::schedule::pack_program;

fn main() {
    let geom = Geometry::paper(1).expect("paper geometry");
    let fast = build_multpim(geom, MultPimVariant::Fast).expect("build");

    section("legalizing the Fast multiplier for minimal (Section 5 'alternatives')");
    let (legal, stats) = legalize_program(&fast.program.ops, ModelKind::Minimal, &geom, GateSet::NotNor, &LegalizeConfig::default())
        .expect("legalize");
    println!("ops in:  {:>6}   (passthrough {})", stats.ops_in, stats.passthrough);
    println!("ops out: {:>6}   latency x{:.3}", legal.len(), legal.len() as f64 / fast.program.ops.len() as f64);

    section("packing the Fast multiplier for unlimited");
    let (packed, pstats) = pack_program(&fast.program.ops, ModelKind::Unlimited, &geom, GateSet::NotNor);
    println!("ops in:  {:>6}   merges {}", pstats.ops_in, pstats.merges);
    println!("ops out: {:>6}   latency x{:.3}", packed.len(), packed.len() as f64 / fast.program.ops.len() as f64);

    section("split-input copy rewrite cost");
    // A semi-parallel op whose gates split their inputs across partitions.
    let op = Operation::Gates(vec![
        GateOp::nor(geom.col(0, 0), geom.col(1, 1), geom.col(2, 3)),
        GateOp::nor(geom.col(8, 0), geom.col(9, 1), geom.col(10, 3)),
    ]);
    let cfg = LegalizeConfig { scratch_intra: Some((30, 31)) };
    let mut st = LegalizeStats::default();
    let out = partition_pim::isa::lower::legalize_op(&op, ModelKind::Standard, &geom, GateSet::NotNor, &cfg, &mut st).expect("legalize");
    println!("1 split-input op -> {} ops ({} copies inserted)", out.len(), st.copies_inserted);

    section("legalizer wall-clock");
    bench("legalize/fast->minimal/full-program", || {
        let (l, _) = legalize_program(&fast.program.ops, ModelKind::Minimal, &geom, GateSet::NotNor, &LegalizeConfig::default())
            .expect("legalize");
        assert!(!l.is_empty());
    });
    bench("pack/fast->unlimited/full-program", || {
        let (p, _) = pack_program(&fast.program.ops, ModelKind::Unlimited, &geom, GateSet::NotNor);
        assert!(!p.is_empty());
    });
}
