//! Figure 6(a) + Section 5.4 — 32-bit multiplication latency (cycles) and
//! energy (gate count) per model, plus wall-clock simulator timing of each
//! program (experiments E6, E9).

use partition_pim::backend::{ExecPipeline, ReplayMode};
use partition_pim::bench_support::{bench, section, throughput};
use partition_pim::coordinator::worker::{compile_workload, workload_geometry, WorkloadKind};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::figures;
use partition_pim::isa::models::ModelKind;

fn main() {
    section("Figure 6(a): 32-bit multiplication latency (paper: 11.3x / 9.2x / 8.6x)");
    let rows = figures::figure6().expect("figure6");
    println!("{:<11} {:>8} {:>9} {:>12} {:>10}", "model", "cycles", "speedup", "gate events", "energy x");
    for r in &rows {
        println!(
            "{:<11} {:>8} {:>8.2}x {:>12} {:>9.2}x",
            r.model.name(),
            r.stats.cycles,
            r.speedup_vs_serial,
            r.stats.gates,
            r.energy_ratio
        );
    }

    section("wall-clock: simulator executing one full multiplication program (64 rows)");
    for model in ModelKind::ALL {
        let geom = workload_geometry(WorkloadKind::Mul32, model, 64).expect("geometry");
        let (prog, _) = compile_workload(WorkloadKind::Mul32, model, geom).expect("compile");
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(1);
        let mut pipe = ExecPipeline::direct(&mut xb);
        let res = bench(&format!("mult32/{}/direct", model.name()), || {
            prog.execute(&mut pipe).expect("run");
        });
        throughput(&res, prog.stats().cycles as f64, "cycles");
    }

    section("wall-clock: full control-message path (encode -> decode -> periphery -> execute)");
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let geom = workload_geometry(WorkloadKind::Mul32, model, 64).expect("geometry");
        let (prog, _) = compile_workload(WorkloadKind::Mul32, model, geom).expect("compile");
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(1);
        let mut pipe = ExecPipeline::wire(model, &mut xb);
        let res = bench(&format!("mult32/{}/messages", model.name()), || {
            prog.execute(&mut pipe).expect("run");
        });
        throughput(&res, prog.stats().cycles as f64, "cycles");
    }

    section("wall-clock: pre-encoded message stream (controller encodes once, periphery re-decodes)");
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let geom = workload_geometry(WorkloadKind::Mul32, model, 64).expect("geometry");
        let (prog, _) = compile_workload(WorkloadKind::Mul32, model, geom).expect("compile");
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(1);
        let mut pipe = ExecPipeline::wire(model, &mut xb);
        pipe.set_replay_mode(ReplayMode::Wire);
        let prepared = prog.prepare(&mut pipe).expect("prepare");
        let res = bench(&format!("mult32/{}/pre-encoded", model.name()), || {
            pipe.run_prepared(&prepared).expect("run");
        });
        throughput(&res, prog.stats().cycles as f64, "cycles");
    }

    section("wall-clock: decoded replay (decode-once trusted op cache, experiment E17)");
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let geom = workload_geometry(WorkloadKind::Mul32, model, 64).expect("geometry");
        let (prog, _) = compile_workload(WorkloadKind::Mul32, model, geom).expect("compile");
        let prepared = {
            let mut scratch = Crossbar::new(geom, GateSet::NotNor);
            prog.prepare(&mut ExecPipeline::wire(model, &mut scratch)).expect("prepare")
        };
        // Parity check before timing: one wire and one decoded replay from the
        // same start state must agree bitwise and in every counter.
        let parity = |mode: ReplayMode| {
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            xb.state.fill_random(1);
            let mut pipe = ExecPipeline::wire(model, &mut xb);
            pipe.set_replay_mode(mode);
            pipe.run_prepared(&prepared).expect("run");
            let (stats, m) = (pipe.stats(), pipe.metrics());
            let counters = (m.cycles, m.gate_events, m.switch_events, stats.control_bits, stats.messages);
            drop(pipe);
            (xb.state, counters)
        };
        assert_eq!(parity(ReplayMode::Decoded), parity(ReplayMode::Wire), "{}: decoded replay diverged", model.name());
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(1);
        let mut pipe = ExecPipeline::wire(model, &mut xb);
        let res = bench(&format!("mult32/{}/decoded-replay", model.name()), || {
            pipe.run_prepared(&prepared).expect("run");
        });
        throughput(&res, prog.stats().cycles as f64, "cycles");
    }
}
