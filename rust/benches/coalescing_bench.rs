//! Experiment E15: the coalescing occupancy sweep — small-job traffic
//! served with and without cross-job chunk coalescing.
//!
//! The crossbar is row-parallel, so a shared program replay costs the same
//! at any occupancy; without coalescing a 1-element job pays the full
//! batch. The sweep submits a fixed element budget as jobs of 1 / 4 / 16 /
//! 64 elements (pipelined, so the coalescer sees real queue depth) and
//! reports elements/s plus the measured mean batch occupancy.
//!
//! Emits `BENCH_coalescing.json` alongside `BENCH_coordinator.json` so CI
//! can track the utilization trajectory across PRs.

use partition_pim::bench_support::{bench, section, throughput};
use partition_pim::coordinator::{PimService, ServiceConfig, WorkloadKind};
use partition_pim::isa::models::ModelKind;

const CROSSBARS: usize = 4;
const ROWS: usize = 64;
const TOTAL_ELEMS: usize = 256;

struct SweepRow {
    job_len: usize,
    coalescing: bool,
    elements_per_sec: f64,
    mean_batch_occupancy: f64,
}

fn run_case(job_len: usize, coalescing: bool) -> SweepRow {
    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars: CROSSBARS,
        rows: ROWS,
        coalescing,
        ..Default::default()
    })
    .expect("service");
    let jobs = TOTAL_ELEMS / job_len;
    let a: Vec<u64> = (0..job_len as u64).map(|i| (i * 2654435761) & 0xffff_ffff).collect();
    let b: Vec<u64> = (0..job_len as u64).map(|i| (i * 40503 + 12345) & 0xffff_ffff).collect();
    let label = format!("coalesce/{}x{}elem/{}", jobs, job_len, if coalescing { "on" } else { "off" });
    let res = bench(&label, || {
        // Pipelined submission: the whole traffic burst is queued before
        // the first wait, as a loaded service would see it.
        let handles: Vec<_> = (0..jobs).map(|_| svc.submit(&a, &b).expect("submit")).collect();
        for h in handles {
            let r = h.wait().expect("wait");
            assert_eq!(r.scalars()[0], a[0] * b[0]);
        }
    });
    throughput(&res, TOTAL_ELEMS as f64, "elements");
    let stats = svc.shutdown();
    let occupancy = stats.mean_occupancy();
    println!("      -> mean batch occupancy {:.1}% over {} batches", 100.0 * occupancy, stats.batches);
    SweepRow {
        job_len,
        coalescing,
        elements_per_sec: TOTAL_ELEMS as f64 / res.mean.as_secs_f64(),
        mean_batch_occupancy: occupancy,
    }
}

fn write_json(rows: &[SweepRow], speedup_1elem: f64) {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"coalescing\",\n");
    s.push_str(&format!(
        "  \"config\": {{\"crossbars\": {CROSSBARS}, \"rows\": {ROWS}, \"total_elements\": {TOTAL_ELEMS}, \"model\": \"minimal\"}},\n"
    ));
    s.push_str("  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"job_len\": {}, \"coalescing\": {}, \"elements_per_sec\": {:.1}, \"mean_batch_occupancy\": {:.4}}}{}\n",
            r.job_len,
            r.coalescing,
            r.elements_per_sec,
            r.mean_batch_occupancy,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!("  ],\n  \"speedup_1elem\": {speedup_1elem:.3}\n}}\n"));
    match std::fs::write("BENCH_coalescing.json", s) {
        Ok(()) => println!("\nwrote BENCH_coalescing.json"),
        Err(e) => println!("\nWARNING: could not write BENCH_coalescing.json: {e}"),
    }
}

fn main() {
    section(&format!(
        "coalescing occupancy sweep: {TOTAL_ELEMS} elements as jobs of 1/4/16/64, {CROSSBARS} crossbars x {ROWS} rows"
    ));
    let mut rows = Vec::new();
    for &job_len in &[1usize, 4, 16, 64] {
        for coalescing in [false, true] {
            rows.push(run_case(job_len, coalescing));
        }
    }
    let eps = |coalescing: bool| {
        rows.iter()
            .find(|r| r.job_len == 1 && r.coalescing == coalescing)
            .map(|r| r.elements_per_sec)
            .unwrap_or(f64::NAN)
    };
    let speedup_1elem = eps(true) / eps(false);
    println!("\ncoalescing speedup on single-element jobs: {speedup_1elem:.2}x");
    write_json(&rows, speedup_1elem);
}
