//! Experiment E16: fleet serving under mixed traffic — p50/p99 job latency
//! and elements/s for a mul + add + sort trace routed across N banks, at
//! two or more bank counts, plus a failover run that kills a bank mid-trace
//! and reports the reroute/promotion cost.
//!
//! Emits `BENCH_fleet.json` so CI can accumulate the serving-tier perf
//! trajectory across PRs (the fleet-level counterpart of
//! `BENCH_coordinator.json`).

use partition_pim::bench_support::section;
use partition_pim::coordinator::worker::{SORT_BITS, SORT_ELEMS};
use partition_pim::coordinator::{FleetConfig, PimFleet, ServiceConfig, WorkloadKind};
use partition_pim::isa::models::ModelKind;
use std::time::Instant;

const CROSSBARS: usize = 2;
const ROWS: usize = 32;
const JOB_LEN: usize = 128;
const SORT_ROWS: usize = 32;
const TRACE_JOBS: usize = 30;
const BANK_COUNTS: [usize; 2] = [3, 6];
const MIX: [WorkloadKind; 3] = [WorkloadKind::Mul32, WorkloadKind::Add32, WorkloadKind::Sort16];

struct TraceRow {
    banks: usize,
    jobs: usize,
    p50_ms: f64,
    p99_ms: f64,
    elements_per_sec: f64,
    mean_occupancy: f64,
}

fn base_config() -> ServiceConfig {
    ServiceConfig { model: ModelKind::Minimal, n_crossbars: CROSSBARS, rows: ROWS, ..Default::default() }
}

fn xorshift(seed: &mut u64) -> u64 {
    *seed ^= *seed << 13;
    *seed ^= *seed >> 7;
    *seed ^= *seed << 17;
    *seed
}

/// Drive one mixed trace through a fleet; returns (per-job wall latencies
/// in ms, elements served, trace wall seconds, reroutes, spares promoted).
fn run_trace(fleet: &PimFleet, kill_bank: Option<usize>) -> (Vec<f64>, u64, f64, u64, u64) {
    let client = fleet.client();
    let mut seed = 0x9e3779b97f4a7c15u64;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for j in 0..TRACE_JOBS {
        let kind = MIX[j % MIX.len()];
        let handle = match kind {
            WorkloadKind::Sort16 => {
                let data: Vec<Vec<u64>> = (0..SORT_ROWS)
                    .map(|_| (0..SORT_ELEMS).map(|_| xorshift(&mut seed) & ((1 << SORT_BITS) - 1)).collect())
                    .collect();
                client.submit_sort(&data).expect("submit_sort")
            }
            _ => {
                let a: Vec<u64> = (0..JOB_LEN).map(|_| xorshift(&mut seed) & 0xffff_ffff).collect();
                let b: Vec<u64> = (0..JOB_LEN).map(|_| xorshift(&mut seed) & 0xffff_ffff).collect();
                client.submit(kind, &a, &b).expect("submit")
            }
        };
        handles.push(handle);
        if kill_bank == Some(j) {
            fleet.kill_bank(0).expect("kill bank 0");
        }
    }
    let mut lat_ms = Vec::with_capacity(handles.len());
    for h in handles {
        let res = h.wait().expect("fleet job");
        lat_ms.push(res.wall.as_secs_f64() * 1e3);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let stats = fleet.stats();
    (lat_ms, stats.aggregate.elements, wall_s, stats.counters.reroutes, stats.counters.spares_promoted)
}

fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    let idx = ((sorted_ms.len() - 1) as f64 * q).round() as usize;
    sorted_ms[idx]
}

fn write_json(rows: &[TraceRow], failover: &str) {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"fleet\",\n");
    s.push_str(&format!(
        "  \"config\": {{\"crossbars_per_bank\": {CROSSBARS}, \"rows\": {ROWS}, \"job_len\": {JOB_LEN}, \"sort_rows\": {SORT_ROWS}, \"trace_jobs\": {TRACE_JOBS}, \"mix\": \"mul32:add32:sort16\"}},\n"
    ));
    s.push_str("  \"bank_counts\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"banks\": {}, \"jobs\": {}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"elements_per_sec\": {:.1}, \"mean_occupancy\": {:.3}}}{}\n",
            r.banks,
            r.jobs,
            r.p50_ms,
            r.p99_ms,
            r.elements_per_sec,
            r.mean_occupancy,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!("  ],\n  \"failover\": {failover}\n}}\n"));
    match std::fs::write("BENCH_fleet.json", s) {
        Ok(()) => println!("\nwrote BENCH_fleet.json"),
        Err(e) => println!("\nWARNING: could not write BENCH_fleet.json: {e}"),
    }
}

fn main() {
    let mut rows = Vec::new();
    for banks in BANK_COUNTS {
        section(&format!(
            "fleet mixed trace: {TRACE_JOBS} jobs (mul/add/sort) across {banks} banks, {CROSSBARS} crossbars x {ROWS} rows each"
        ));
        let cfg = FleetConfig::mixed(&MIX, banks, base_config()).expect("fleet config");
        let fleet = PimFleet::start(cfg).expect("fleet");
        let (mut lat_ms, elements, wall_s, _, _) = run_trace(&fleet, None);
        let stats = fleet.shutdown();
        lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
        let (p50, p99) = (percentile(&lat_ms, 0.50), percentile(&lat_ms, 0.99));
        let eps = elements as f64 / wall_s;
        println!("      p50 {p50:.3} ms   p99 {p99:.3} ms   {eps:.0} elements/s   occupancy {:.1}%", 100.0 * stats.aggregate.mean_occupancy());
        rows.push(TraceRow {
            banks,
            jobs: TRACE_JOBS,
            p50_ms: p50,
            p99_ms: p99,
            elements_per_sec: eps,
            mean_occupancy: stats.aggregate.mean_occupancy(),
        });
    }

    section("fleet failover: bank 0 killed mid-trace (1 hot spare), every job must still complete");
    let mut cfg = FleetConfig::mixed(&MIX, BANK_COUNTS[0], base_config()).expect("fleet config");
    cfg.spare_slots = 1;
    let fleet = PimFleet::start(cfg).expect("fleet");
    let (mut lat_ms, _, _, reroutes, promoted) = run_trace(&fleet, Some(TRACE_JOBS / 2));
    let stats = fleet.shutdown();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    let p99 = percentile(&lat_ms, 0.99);
    assert_eq!(stats.aggregate.jobs, TRACE_JOBS as u64, "every accepted job must complete despite the bank death");
    println!(
        "      completed {}/{TRACE_JOBS} jobs   reroutes {reroutes}   spares promoted {promoted}   p99 {p99:.3} ms",
        stats.aggregate.jobs
    );
    let failover = format!(
        "{{\"banks\": {}, \"killed_bank\": 0, \"completed_jobs\": {}, \"reroutes\": {reroutes}, \"spares_promoted\": {promoted}, \"p99_ms\": {p99:.3}}}",
        BANK_COUNTS[0], stats.aggregate.jobs
    );

    write_json(&rows, &failover);
}
