//! Experiment E13: end-to-end coordinator throughput — batched 32-bit
//! vector multiplication served by a bank of crossbars, per model.

use partition_pim::bench_support::{bench, section, throughput};
use partition_pim::coordinator::{PimService, ServiceConfig, WorkloadKind};
use partition_pim::isa::models::ModelKind;

fn main() {
    section("service throughput: 256-element multiply jobs, 4 crossbars x 64 rows");
    for model in [ModelKind::Minimal, ModelKind::Standard, ModelKind::Unlimited] {
        let mut svc = PimService::start(ServiceConfig { kind: WorkloadKind::Mul32, model, n_crossbars: 4, rows: 64 })
            .expect("service");
        let a: Vec<u64> = (0..256).map(|i| (i * 2654435761) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..256).map(|i| (i * 40503 + 12345) & 0xffff_ffff).collect();
        let res = bench(&format!("service/mul32/{}", model.name()), || {
            let r = svc.submit(&a, &b).expect("submit");
            assert_eq!(r.values[3], a[3] * b[3]);
        });
        throughput(&res, 256.0, "mults");
        let stats = svc.shutdown();
        println!(
            "      simulated: {:.2} elements/kilocycle, {:.1} control bits/element",
            1000.0 * stats.elements as f64 / stats.metrics.cycles as f64,
            stats.metrics.control_bits as f64 / stats.elements as f64
        );
    }

    section("batching ablation: rows per crossbar (minimal model)");
    for rows in [8usize, 32, 128] {
        let mut svc = PimService::start(ServiceConfig { kind: WorkloadKind::Mul32, model: ModelKind::Minimal, n_crossbars: 4, rows })
            .expect("service");
        let a: Vec<u64> = (0..256).map(|i| (i * 7919) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..256).map(|i| (i * 104729) & 0xffff_ffff).collect();
        let res = bench(&format!("service/batch-rows-{rows}"), || {
            svc.submit(&a, &b).expect("submit");
        });
        throughput(&res, 256.0, "mults");
        svc.shutdown();
    }
}
