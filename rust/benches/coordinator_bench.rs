//! Experiment E13: end-to-end coordinator throughput — batched 32-bit
//! vector multiplication served by a bank of crossbars, per model — plus
//! the concurrent-scheduler ablation (pipelined vs serial submission).
//!
//! Emits `BENCH_coordinator.json` (per-model elements/s and sim-cycles per
//! element) so CI can accumulate the perf trajectory across PRs.

use partition_pim::bench_support::{bench, section, throughput};
use partition_pim::coordinator::{PimService, ServiceConfig, WorkloadKind};
use partition_pim::isa::models::ModelKind;

const JOB_LEN: usize = 256;
const CROSSBARS: usize = 4;
const ROWS: usize = 64;

struct ModelRow {
    model: &'static str,
    elements_per_sec: f64,
    sim_cycles_per_element: f64,
    control_bits_per_element: f64,
}

fn write_json(rows: &[ModelRow], pipelined_speedup: f64) {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"coordinator\",\n");
    s.push_str(&format!(
        "  \"config\": {{\"crossbars\": {CROSSBARS}, \"rows\": {ROWS}, \"job_len\": {JOB_LEN}}},\n"
    ));
    s.push_str("  \"models\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"model\": \"{}\", \"elements_per_sec\": {:.1}, \"sim_cycles_per_element\": {:.3}, \"control_bits_per_element\": {:.3}}}{}\n",
            r.model,
            r.elements_per_sec,
            r.sim_cycles_per_element,
            r.control_bits_per_element,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!("  ],\n  \"pipelined_speedup\": {pipelined_speedup:.3}\n}}\n"));
    match std::fs::write("BENCH_coordinator.json", s) {
        Ok(()) => println!("\nwrote BENCH_coordinator.json"),
        Err(e) => println!("\nWARNING: could not write BENCH_coordinator.json: {e}"),
    }
}

fn main() {
    let mut json_rows: Vec<ModelRow> = Vec::new();
    section(&format!("service throughput: {JOB_LEN}-element multiply jobs, {CROSSBARS} crossbars x {ROWS} rows"));
    for model in [ModelKind::Minimal, ModelKind::Standard, ModelKind::Unlimited] {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model,
            n_crossbars: CROSSBARS,
            rows: ROWS,
            ..Default::default()
        })
        .expect("service");
        let a: Vec<u64> = (0..JOB_LEN as u64).map(|i| (i * 2654435761) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..JOB_LEN as u64).map(|i| (i * 40503 + 12345) & 0xffff_ffff).collect();
        let res = bench(&format!("service/mul32/{}", model.name()), || {
            let r = svc.submit(&a, &b).expect("submit").wait().expect("wait");
            assert_eq!(r.scalars()[3], a[3] * b[3]);
        });
        throughput(&res, JOB_LEN as f64, "mults");
        let stats = svc.shutdown();
        let sim_cycles_per_element = stats.metrics.cycles as f64 / stats.elements as f64;
        let control_bits_per_element = stats.metrics.control_bits as f64 / stats.elements as f64;
        println!(
            "      simulated: {:.2} elements/kilocycle, {:.1} control bits/element",
            1000.0 / sim_cycles_per_element,
            control_bits_per_element
        );
        json_rows.push(ModelRow {
            model: model.name(),
            elements_per_sec: JOB_LEN as f64 / res.mean.as_secs_f64(),
            sim_cycles_per_element,
            control_bits_per_element,
        });
    }

    section("scheduler ablation: pipelined vs serial submission (minimal, 8 jobs x 128 elements)");
    let mk = || {
        PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 4,
            rows: 16,
            ..Default::default()
        })
        .expect("service")
    };
    let a: Vec<u64> = (0..128u64).map(|i| (i * 7919) & 0xffff_ffff).collect();
    let b: Vec<u64> = (0..128u64).map(|i| (i * 104729) & 0xffff_ffff).collect();
    let svc = mk();
    let serial = bench("service/submit-serial", || {
        for _ in 0..8 {
            svc.submit(&a, &b).expect("submit").wait().expect("wait");
        }
    });
    svc.shutdown();
    let svc = mk();
    let pipelined = bench("service/submit-pipelined", || {
        let handles: Vec<_> = (0..8).map(|_| svc.submit(&a, &b).expect("submit")).collect();
        for h in handles {
            h.wait().expect("wait");
        }
    });
    svc.shutdown();
    let pipelined_speedup = serial.mean_ns() / pipelined.mean_ns();
    println!("      -> pipelined speedup: {pipelined_speedup:.2}x");

    section("batching ablation: rows per crossbar (minimal model)");
    for rows in [8usize, 32, 128] {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 4,
            rows,
            ..Default::default()
        })
        .expect("service");
        let a: Vec<u64> = (0..256u64).map(|i| (i * 7919) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..256u64).map(|i| (i * 104729) & 0xffff_ffff).collect();
        let res = bench(&format!("service/batch-rows-{rows}"), || {
            svc.submit(&a, &b).expect("submit").wait().expect("wait");
        });
        throughput(&res, 256.0, "mults");
        svc.shutdown();
    }

    write_json(&json_rows, pipelined_speedup);
}
