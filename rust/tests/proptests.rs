//! Hand-rolled property-based tests (proptest is not in the offline vendor
//! set — see DESIGN.md §Substitutions). Each property runs hundreds of
//! randomized cases from a deterministic PRNG and shrinks failures by
//! printing the seed.
//!
//! Properties:
//!  P1  simulator gate semantics == naive bool-matrix model
//!  P2  legalizer preserves program semantics for every model
//!  P3  packer preserves semantics and never increases cycle count
//!  P4  tight section division is consistent with operation spans
//!  P5  opcode generator output composes into valid half-gate pairs
//!  P6  range-generator expansion matches the minimal-model validator
//!  P7  coordinator batching: any split of a job gives identical results
//!  P10 differential: random legal programs execute to the identical
//!      BitMatrix on the bit-packed and the scalar reference backend,
//!      driven through the same `&mut dyn PimBackend` trait object
//!  P11 differential: the wire pipeline (encode → periphery decode) on one
//!      backend matches the direct pipeline on the other
//!  P12 verifier differential: random legal programs are verifier-clean
//!      under the unlimited model, and verifier-clean programs execute
//!      bitwise-identically on the bit-packed and scalar backends
//!  P14 replay differential: for random verifier-clean programs, the
//!      decode-once cached replay is bitwise- and metric-identical to the
//!      full wire-path replay on both backends, serial and word-parallel
//!  P15 SHA-3 differential: random 1600-bit Keccak states permuted by the
//!      HashPIM crossbar program (wire pipeline) are bitwise-equal to the
//!      pure-software Keccak-f[1600] oracle on the bit-packed backend and
//!      on the scalar reference backend

use partition_pim::algorithms::program::Builder;
use partition_pim::backend::{ExecPipeline, PimBackend, ScalarCrossbar};
use partition_pim::coordinator::{PimService, ServiceConfig, WorkloadKind};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::{GateSet, GateType};
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::lower::{legalize_program, LegalizeConfig};
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::operation::{Direction, GateOp, Operation};
use partition_pim::isa::schedule::pack_program;
use partition_pim::periphery::{halfgate, opcode_gen, range_gen};

struct Rng(u64);
impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }
    fn flag(&mut self) -> bool {
        self.next() & 1 == 1
    }
}

/// Random physically-valid (unlimited-legal) operation.
fn random_op(rng: &mut Rng, geom: &Geometry) -> Operation {
    if rng.below(5) == 0 {
        let cols: Vec<usize> = (0..1 + rng.below(8)).map(|_| rng.below(geom.n)).collect();
        return Operation::Init { cols, value: rng.flag() };
    }
    // Build gates over random disjoint partition intervals.
    let mut gates = Vec::new();
    let mut p = 0usize;
    while p < geom.k {
        if rng.below(3) == 0 {
            let span = 1 + rng.below((geom.k - p).min(3));
            let (plo, phi) = (p, p + span - 1);
            let pick = |rng: &mut Rng| plo + rng.below(phi - plo + 1);
            let pa = pick(rng);
            let pb = pick(rng);
            let po = if rng.flag() { plo } else { phi };
            let a = geom.col(pa, rng.below(geom.m()));
            let b = geom.col(pb, rng.below(geom.m()));
            let mut o = geom.col(po, rng.below(geom.m()));
            let mut guard = 0;
            while (o == a || o == b) && guard < 50 {
                o = geom.col(po, rng.below(geom.m()));
                guard += 1;
            }
            if o != a && o != b {
                gates.push(if rng.below(4) == 0 { GateOp::not(a, o) } else { GateOp::nor(a, b, o) });
            }
            p += span;
        } else {
            p += 1;
        }
    }
    if gates.is_empty() {
        let a = geom.col(0, 0);
        gates.push(GateOp::not(a, geom.col(0, 1)));
    }
    Operation::Gates(gates)
}

/// P1: word-packed simulator == naive per-bit model.
#[test]
fn p1_simulator_matches_naive_model() {
    let geom = Geometry::new(128, 4, 70).unwrap(); // odd row count: tail masking
    for seed in 1..40u64 {
        let mut rng = Rng::new(seed * 7919);
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(seed);
        // Naive model: Vec<Vec<bool>> [row][col].
        let mut naive: Vec<Vec<bool>> = (0..geom.rows).map(|r| (0..geom.n).map(|c| xb.state.get(r, c)).collect()).collect();
        for _ in 0..30 {
            let op = random_op(&mut rng, &geom);
            xb.execute(&op).expect("execute");
            match &op {
                Operation::Init { cols, value } => {
                    for &c in cols {
                        for row in naive.iter_mut() {
                            row[c] = *value;
                        }
                    }
                }
                Operation::Gates(gates) => {
                    let snapshot = naive.clone();
                    for g in gates {
                        for r in 0..geom.rows {
                            let ins: Vec<bool> = g.ins.iter().map(|&c| snapshot[r][c]).collect();
                            naive[r][g.out] = match g.gate {
                                GateType::Not => !ins[0],
                                GateType::Nor => !(ins[0] | ins[1]),
                                _ => unreachable!(),
                            };
                        }
                    }
                }
            }
        }
        for r in 0..geom.rows {
            for c in 0..geom.n {
                assert_eq!(xb.state.get(r, c), naive[r][c], "seed {seed} at ({r}, {c})");
            }
        }
    }
}

/// P2: legalization preserves semantics under every model.
#[test]
fn p2_legalizer_preserves_semantics() {
    let geom = Geometry::new(256, 8, 33).unwrap();
    let cfg = LegalizeConfig { scratch_intra: Some((30, 31)) };
    for seed in 1..30u64 {
        let mut rng = Rng::new(seed * 104729);
        // Random program avoiding the reserved scratch columns.
        let mut ops = Vec::new();
        for _ in 0..15 {
            let op = random_op(&mut rng, &geom);
            let uses_scratch = match &op {
                Operation::Init { cols, .. } => cols.iter().any(|&c| geom.intra(c) >= 30),
                Operation::Gates(gs) => gs.iter().any(|g| geom.intra(g.out) >= 30 || g.ins.iter().any(|&c| geom.intra(c) >= 30)),
            };
            if !uses_scratch {
                ops.push(op);
            }
        }
        if ops.is_empty() {
            continue;
        }
        for model in ModelKind::ALL {
            let (legal, _) = legalize_program(&ops, model, &geom, GateSet::NotNor, &cfg)
                .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", model.name()));
            for op in &legal {
                model.check(op, &geom, GateSet::NotNor).expect("legalized op must validate");
            }
            let mut a = Crossbar::new(geom, GateSet::NotNor);
            a.state.fill_random(seed);
            let mut b = a.clone();
            a.execute_ops(&ops).expect("original");
            b.execute_ops(&legal).expect("legalized");
            // Compare everything except the reserved scratch columns.
            for r in 0..geom.rows {
                for c in 0..geom.n {
                    if geom.intra(c) >= 30 {
                        continue;
                    }
                    assert_eq!(a.state.get(r, c), b.state.get(r, c), "seed {seed} {} at ({r}, {c})", model.name());
                }
            }
        }
    }
}

/// P3: the packer preserves semantics and only shortens programs.
#[test]
fn p3_packer_preserves_semantics() {
    let geom = Geometry::new(256, 8, 65).unwrap();
    for seed in 1..40u64 {
        let mut rng = Rng::new(seed * 31337);
        let ops: Vec<Operation> = (0..20).map(|_| random_op(&mut rng, &geom)).collect();
        for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
            let (packed, stats) = pack_program(&ops, model, &geom, GateSet::NotNor);
            assert!(packed.len() <= ops.len());
            assert_eq!(stats.ops_in - stats.merges, packed.len());
            let mut a = Crossbar::new(geom, GateSet::NotNor);
            a.state.fill_random(seed);
            let mut b = a.clone();
            a.execute_ops(&ops).expect("original");
            b.execute_ops(&packed).expect("packed");
            assert_eq!(a.state, b.state, "seed {seed} {}", model.name());
        }
    }
}

/// P4: tight selects conduct exactly inside gate spans.
#[test]
fn p4_tight_selects_match_spans() {
    let geom = Geometry::new(256, 8, 8).unwrap();
    for seed in 1..100u64 {
        let mut rng = Rng::new(seed * 3);
        let op = random_op(&mut rng, &geom);
        if matches!(op, Operation::Init { .. }) {
            continue;
        }
        let selects = op.tight_selects(&geom);
        let sections = op.sections(&geom);
        for t in 0..geom.k - 1 {
            let inside = sections.iter().any(|&(lo, hi)| t >= lo && t < hi);
            assert_eq!(!selects[t], inside, "seed {seed} transistor {t}");
        }
    }
}

/// P5: generated opcodes always reconstruct (no dangling half-gates) for
/// arbitrary tight divisions with edge-enabled sections.
#[test]
fn p5_opcode_generator_composes() {
    let geom = Geometry::new(256, 8, 8).unwrap();
    for seed in 1..200u64 {
        let mut rng = Rng::new(seed * 17);
        // Random section division; enable first+last partition of randomly
        // chosen sections.
        let selects: Vec<bool> = (0..geom.k - 1).map(|_| rng.flag()).collect();
        let mut enables = vec![false; geom.k];
        let mut any = false;
        for (lo, hi) in halfgate::sections_from_selects(&selects) {
            if rng.flag() {
                enables[lo] = true;
                enables[hi] = true;
                any = true;
            }
        }
        if !any {
            continue;
        }
        let dir = if rng.flag() { Direction::InputsLeft } else { Direction::OutputsLeft };
        let opcodes = opcode_gen::generate(&enables, &selects, dir).expect("generate");
        // Compose into fields with shared indices and reconstruct.
        let parts: Vec<partition_pim::isa::encode::PartitionFields> =
            opcodes.into_iter().map(|opcode| partition_pim::isa::encode::PartitionFields { ia: 0, ib: 1, io: 3, opcode }).collect();
        halfgate::reconstruct_from_fields(&parts, &selects, &geom)
            .unwrap_or_else(|e| panic!("seed {seed}: dangling half-gates from generated opcodes: {e}"));
    }
}

/// P6: range-generator expansions are exactly the operations the minimal
/// validator accepts.
#[test]
fn p6_range_generator_matches_validator() {
    let geom = Geometry::new(256, 8, 8).unwrap();
    for seed in 1..300u64 {
        let mut rng = Rng::new(seed * 23);
        let d = rng.below(4);
        let t = 1 + rng.below(6);
        let p_start = rng.below(geom.k);
        let p_end = p_start + rng.below(geom.k - p_start);
        let dir = if rng.flag() { Direction::InputsLeft } else { Direction::OutputsLeft };
        let params = range_gen::RangeParams { p_start, p_end, t, distance: d, dir };
        match range_gen::expand(&params, geom.k) {
            Err(_) => {} // rejected patterns are fine
            Ok(e) => {
                // Build the operation the expansion implies and check it is
                // minimal-legal.
                let gates: Vec<GateOp> = (0..geom.k)
                    .filter(|&p| e.in_mask[p])
                    .map(|p| {
                        let q = match dir {
                            Direction::InputsLeft => p + d,
                            Direction::OutputsLeft => p - d,
                        };
                        GateOp::nor(geom.col(p, 0), geom.col(p, 1), geom.col(q, 3))
                    })
                    .collect();
                let op = Operation::Gates(gates);
                ModelKind::Minimal
                    .check(&op, &geom, GateSet::NotNor)
                    .unwrap_or_else(|err| panic!("seed {seed}: expansion {params:?} not minimal-legal: {err}"));
            }
        }
    }
}

/// P7: splitting a job across different chunk sizes / bank widths never
/// changes results.
#[test]
fn p7_batching_invariance() {
    let (a, b): (Vec<u64>, Vec<u64>) = {
        let mut rng = Rng::new(777);
        ((0..33).map(|_| rng.next() & 0xffff_ffff).collect(), (0..33).map(|_| rng.next() & 0xffff_ffff).collect())
    };
    let mut reference: Option<Vec<u64>> = None;
    for (crossbars, rows) in [(1usize, 33usize), (2, 8), (4, 5), (3, 1)] {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: crossbars,
            rows,
            ..Default::default()
        })
        .expect("service");
        let res = svc.submit(&a, &b).expect("submit").wait().expect("wait");
        svc.shutdown();
        let values = res.scalars().to_vec();
        match &reference {
            None => reference = Some(values),
            Some(r) => assert_eq!(&values, r, "{crossbars} crossbars x {rows} rows"),
        }
    }
}

/// Builder misuse is rejected (negative-space checks).
#[test]
fn builder_rejects_invalid_programs() {
    let geom = Geometry::new(256, 8, 8).unwrap();
    let mut b = Builder::new(geom, GateSet::NotNor);
    assert!(b.nor(0, 1, 0).is_err()); // out aliases input
    assert!(b.nor(0, 1, 999).is_err()); // out of range
    assert!(b.push(Operation::Gates(vec![])).is_err()); // empty cycle
    assert!(b
        .push(Operation::Gates(vec![
            GateOp::nor(geom.col(0, 0), geom.col(0, 1), geom.col(1, 3)),
            GateOp::nor(geom.col(1, 0), geom.col(1, 1), geom.col(1, 5)),
        ]))
        .is_err()); // overlapping sections
}

/// P8: BitVec push/read round-trips for arbitrary field sequences — the
/// wire format's foundation after the u64-packing optimization.
#[test]
fn p8_bitvec_roundtrip() {
    use partition_pim::isa::encode::{BitReader, BitVec};
    for seed in 1..200u64 {
        let mut rng = Rng::new(seed * 41);
        let fields: Vec<(usize, usize)> = (0..1 + rng.below(40))
            .map(|_| {
                let width = 1 + rng.below(64);
                let value = (rng.next() as usize) & if width >= 64 { usize::MAX } else { (1usize << width) - 1 };
                (value, width)
            })
            .collect();
        let mut bv = BitVec::new();
        for &(v, w) in &fields {
            bv.push_bits(v, w);
        }
        assert_eq!(bv.len(), fields.iter().map(|&(_, w)| w).sum::<usize>());
        let mut r = BitReader::new(&bv);
        for &(v, w) in &fields {
            assert_eq!(r.read_bits(w).unwrap(), v, "seed {seed} width {w}");
        }
        r.finish().unwrap();
        // get() agrees with sequential reads.
        let mut r2 = BitReader::new(&bv);
        for i in 0..bv.len() {
            assert_eq!(r2.read_bit().unwrap(), bv.get(i), "seed {seed} bit {i}");
        }
    }
}

/// Build a `Program` from random physically-valid operations (the builder
/// validates every cycle, so the result is a *legal* program by
/// construction).
fn random_program(rng: &mut Rng, geom: Geometry, len: usize) -> partition_pim::algorithms::program::Program {
    let mut b = Builder::new(geom, GateSet::NotNor);
    for _ in 0..len {
        b.push(random_op(rng, &geom)).expect("random_op generates valid operations");
    }
    b.finish("fuzz")
}

/// P10 (differential): any random legal program executes to the identical
/// final `BitMatrix` on the bit-packed backend and the scalar reference
/// backend, driven through the same `&mut dyn PimBackend` trait object —
/// and the architectural counters (cycles, gates, switching energy) agree
/// exactly.
#[test]
fn p10_backends_agree_on_random_programs() {
    let geom = Geometry::new(128, 4, 37).unwrap(); // odd rows: tail masking
    for seed in 1..25u64 {
        let mut rng = Rng::new(seed * 6151);
        let prog = random_program(&mut rng, geom, 25);
        let mut init = partition_pim::crossbar::state::BitMatrix::new(geom.rows, geom.n);
        init.fill_random(seed);

        let mut bitpacked = Crossbar::new(geom, GateSet::NotNor);
        let mut scalar = ScalarCrossbar::new(geom, GateSet::NotNor);
        let mut finals = Vec::new();
        let mut metrics = Vec::new();
        let backends: [&mut dyn PimBackend; 2] = [&mut bitpacked, &mut scalar];
        for backend in backends {
            backend.load_state(&init).expect("load");
            prog.execute(&mut ExecPipeline::direct(&mut *backend)).expect("execute");
            finals.push(backend.state_bits().expect("state"));
            metrics.push(backend.metrics());
        }
        assert_eq!(finals[0], finals[1], "seed {seed}: backends diverged");
        assert_eq!(metrics[0], metrics[1], "seed {seed}: counters diverged");
    }
}

/// P11 (differential): the full wire pipeline (encode → periphery decode →
/// trusted execute) on the bit-packed backend matches the direct pipeline
/// on the scalar oracle, and the metered control traffic is exactly
/// messages x format length.
#[test]
fn p11_wire_pipeline_matches_scalar_oracle() {
    use partition_pim::crossbar::crossbar::init_message_bits;
    use partition_pim::isa::encode::message_bits;
    let geom = Geometry::new(256, 8, 18).unwrap();
    for seed in 1..15u64 {
        let mut rng = Rng::new(seed * 2861);
        let prog = random_program(&mut rng, geom, 20);
        let mut init = partition_pim::crossbar::state::BitMatrix::new(geom.rows, geom.n);
        init.fill_random(seed * 3 + 1);

        let mut bitpacked = Crossbar::new(geom, GateSet::NotNor);
        bitpacked.load_state(&init).expect("load");
        let mut pipe = ExecPipeline::wire(ModelKind::Unlimited, &mut bitpacked);
        prog.execute(&mut pipe).expect("wire execute");
        let stats = pipe.stats();
        let gate_cycles = prog.ops.iter().filter(|op| matches!(op, Operation::Gates(_))).count() as u64;
        let init_cycles = prog.ops.len() as u64 - gate_cycles;
        assert_eq!(stats.messages, prog.ops.len() as u64, "seed {seed}");
        assert_eq!(
            stats.control_bits,
            gate_cycles * message_bits(ModelKind::Unlimited, &geom) as u64 + init_cycles * init_message_bits(&geom) as u64,
            "seed {seed}"
        );
        drop(pipe);

        let mut scalar = ScalarCrossbar::new(geom, GateSet::NotNor);
        scalar.load_state(&init).expect("load");
        prog.execute(&mut ExecPipeline::direct(&mut scalar)).expect("direct execute");
        assert_eq!(
            bitpacked.state_bits().expect("state"),
            scalar.state_bits().expect("state"),
            "seed {seed}: wire pipeline diverged from the scalar oracle"
        );
    }
}

/// P12 (verifier differential): every random legal program is
/// verifier-clean under the unlimited model (hazard-free by construction;
/// mixed directions are at most V012 warnings), and every verifier-clean
/// program executes to the identical final `BitMatrix` on the bit-packed
/// backend and the scalar oracle — static cleanliness is evidence of
/// dynamic agreement, never a substitute for it.
#[test]
fn p12_verifier_clean_programs_agree_across_backends() {
    use partition_pim::verify::{verify_ops, VerifyOptions};
    let geom = Geometry::new(256, 8, 21).unwrap();
    for seed in 1..25u64 {
        let mut rng = Rng::new(seed * 9337);
        let prog = random_program(&mut rng, geom, 20);
        let report = verify_ops(&prog.name, &prog.ops, &geom, &VerifyOptions::new(ModelKind::Unlimited, GateSet::NotNor));
        assert!(report.is_clean(), "seed {seed}: random legal program must verify clean\n{}", report.render());

        let mut init = partition_pim::crossbar::state::BitMatrix::new(geom.rows, geom.n);
        init.fill_random(seed * 5 + 2);
        let mut bitpacked = Crossbar::new(geom, GateSet::NotNor);
        let mut scalar = ScalarCrossbar::new(geom, GateSet::NotNor);
        let mut finals = Vec::new();
        let backends: [&mut dyn PimBackend; 2] = [&mut bitpacked, &mut scalar];
        for backend in backends {
            backend.load_state(&init).expect("load");
            prog.execute(&mut ExecPipeline::direct(&mut *backend)).expect("execute");
            finals.push(backend.state_bits().expect("state"));
        }
        assert_eq!(finals[0], finals[1], "seed {seed}: verifier-clean program diverged across backends");
    }
}

/// P14 (replay differential): for random verifier-clean programs, replaying
/// through the decode-once trusted op cache is bitwise- and metric-identical
/// (final states, `switch_events`, `control_bits`, `messages`) to the full
/// wire-path replay — on the bit-packed backend both serially and across
/// parallel word ranges, and on the scalar oracle.
#[test]
fn p14_decoded_replay_matches_wire_replay() {
    use partition_pim::backend::ReplayMode;
    use partition_pim::verify::{verify_ops, VerifyOptions};
    let geom = Geometry::new(256, 8, 130).unwrap(); // 3 words/col: real word ranges
    for seed in 1..15u64 {
        let mut rng = Rng::new(seed * 7877);
        let prog = random_program(&mut rng, geom, 20);
        let report = verify_ops(&prog.name, &prog.ops, &geom, &VerifyOptions::new(ModelKind::Unlimited, GateSet::NotNor));
        assert!(report.is_clean(), "seed {seed}: random legal program must verify clean");
        let mut init = partition_pim::crossbar::state::BitMatrix::new(geom.rows, geom.n);
        init.fill_random(seed * 11 + 3);

        let prepared = {
            let mut scratch = Crossbar::new(geom, GateSet::NotNor);
            prog.prepare(&mut ExecPipeline::wire(ModelKind::Unlimited, &mut scratch)).expect("prepare")
        };
        assert!(prepared.is_decoded(), "seed {seed}: wire prepare must attach the decoded cache");

        let mut outcomes = Vec::new();
        for (mode, threads, bitpacked) in [
            (ReplayMode::Wire, 1, true),
            (ReplayMode::Decoded, 1, true),
            (ReplayMode::Decoded, 3, true),
            (ReplayMode::Wire, 1, false),
            (ReplayMode::Decoded, 1, false),
        ] {
            let mut bp = Crossbar::new(geom, GateSet::NotNor);
            let mut sc = ScalarCrossbar::new(geom, GateSet::NotNor);
            let backend: &mut dyn PimBackend = if bitpacked { &mut bp } else { &mut sc };
            backend.load_state(&init).expect("load");
            let mut pipe = ExecPipeline::wire(ModelKind::Unlimited, backend);
            pipe.set_replay_mode(mode);
            pipe.set_replay_threads(threads);
            pipe.run_prepared(&prepared).expect("replay");
            let stats = pipe.stats();
            let metrics = pipe.metrics();
            outcomes.push((
                pipe.backend().state_bits().expect("state"),
                metrics.switch_events,
                stats.control_bits,
                stats.messages,
            ));
        }
        for (i, o) in outcomes.iter().enumerate().skip(1) {
            assert_eq!(o, &outcomes[0], "seed {seed}: replay configuration {i} diverged");
        }
    }
}

/// P9: flipping any single bit of a valid message never round-trips to the
/// original operation unchanged *and* undetected in length — i.e. the
/// codec has no dead bits for the operations it encodes... except fields
/// that are genuinely don't-care for the op (e.g. unused partitions'
/// indices in the unlimited format). Here we assert the weaker, always-true
/// property: decode never panics and lengths are always enforced.
#[test]
fn p9_single_bitflip_safety() {
    use partition_pim::isa::encode::{decode, encode};
    use partition_pim::periphery;
    let geom = Geometry::new(256, 8, 8).unwrap();
    let op = Operation::Gates((0..8).map(|p| GateOp::nor(geom.col(p, 0), geom.col(p, 1), geom.col(p, 3))).collect());
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let bits = encode(model, &op, &geom).unwrap();
        for i in 0..bits.len() {
            let mut corrupted = bits.clone();
            corrupted.flip(i);
            if let Ok(msg) = decode(model, &corrupted, &geom) {
                // Reconstruction either fails cleanly or yields a valid op.
                if let Ok(rec) = periphery::reconstruct(&msg, &geom) {
                    rec.validate(&geom, GateSet::NotNor).expect("reconstructed ops are always physically valid");
                }
            }
        }
    }
}

/// P15 (SHA-3 differential): random 1600-bit states run through the
/// HashPIM Keccak-f[1600] program — wire pipeline, typed-message codec —
/// are bitwise-equal to the software oracle on the bit-packed backend and
/// on the scalar reference backend.
#[test]
fn p15_sha3_differential_against_oracle() {
    use partition_pim::algorithms::sha3;
    let geom = partition_pim::coordinator::workload_geometry(WorkloadKind::Sha3, ModelKind::Minimal, 2).unwrap();
    let unit = sha3::build_keccak_f(geom).expect("build keccak_f");
    for seed in 1..4u64 {
        let mut rng = Rng::new(seed * 6007);
        let states: Vec<[u64; 25]> = (0..geom.rows)
            .map(|_| {
                let mut st = [0u64; 25];
                for lane in st.iter_mut() {
                    *lane = rng.next();
                }
                st
            })
            .collect();
        let mut expect = states.clone();
        for st in &mut expect {
            sha3::keccak_f_sw(st);
        }
        let mut bp = Crossbar::new(geom, GateSet::HashPim);
        let mut sc = ScalarCrossbar::new(geom, GateSet::HashPim);
        for (backend, label) in [(&mut bp as &mut dyn PimBackend, "bit-packed"), (&mut sc, "scalar")] {
            let mut init = partition_pim::crossbar::state::BitMatrix::new(geom.rows, geom.n);
            for (r, st) in states.iter().enumerate() {
                unit.load(&mut init, r, st).expect("load");
            }
            backend.load_state(&init).expect("load_state");
            unit.program.execute(&mut ExecPipeline::wire(ModelKind::Minimal, backend)).expect("execute");
            let out = backend.state_bits().expect("state");
            for (r, want) in expect.iter().enumerate() {
                let got = unit.read(&out, r).expect("read");
                assert_eq!(&got, want, "seed {seed}: {label} backend diverged from the software oracle on row {r}");
            }
        }
    }
}
