//! SHA-3 (HashPIM) per-step cycle/gate accounting, held against the
//! published HashPIM round table:
//!
//! | step  | cycles | gates   |
//! |-------|--------|---------|
//! | Theta |    330 |  15,127 |
//! | Rho   |  2,911 |  82,300 |
//! | Pi    |     81 |   6,976 |
//! | Chi   |    140 |  14,720 |
//! | Iota  |     32 |     448 |
//! | round |  3,494 | 119,571 |
//!
//! This reproduction lands *under* the published budget on every step, for
//! two documented reasons rather than by accident:
//!
//! 1. **z-dimension bit-slicing.** HashPIM tiles several Keccak states into
//!    one array and serializes along the 64-bit lane dimension; here lane
//!    bit `z` lives in partition `z`, so one concurrent cycle advances all
//!    64 bits of a lane step (and the row dimension carries independent
//!    states). Rotation-heavy steps (Rho: published 2,911 cycles) collapse
//!    to grouped inter-partition copies — `2·min(r, 64-r) + 2` cycles per
//!    lane under the *minimal* control model's section/periodicity rules.
//! 2. **Native XOR.** The wire format's per-cycle gate-type field makes
//!    XOR a single-cycle stateful gate, so Theta's parity folds and Chi's
//!    final mix don't pay the published multi-gate XOR decompositions.
//!
//! The emitted counts asserted below are exact and deterministic (the
//! builder's schedule has no randomness), so any schedule regression —
//! a lost gate grouping, an extra init cycle — fails this test, not just
//! the generous published bound.

use partition_pim::algorithms::sha3::{
    build_keccak_f, build_keccak_round, Sha3StepStats, LANE_BITS, PUBLISHED_ROUND_CYCLES, PUBLISHED_ROUND_GATES,
    PUBLISHED_STEP_TABLE, ROUNDS,
};
use partition_pim::crossbar::geometry::Geometry;

fn geom() -> Geometry {
    Geometry::new(4096, LANE_BITS, 4).unwrap()
}

/// Exact emitted schedule, derived in the module docs of
/// `algorithms::sha3`:
///
/// * Theta: 5×(1 init + 4 parity folds) + 5×(4-cycle rot1 + init + XOR)
///   + (init + 25 D-folds) = 81 cycles / 3,520 gates.
/// * Rho: identity lane 2 cycles + Σ over the 24 rotated lanes of
///   `2·min(r, 64-r) + 2` (Σ min = 356) = 762 cycles / 1,600 gates.
/// * Pi: 1 init + 25 distance-0 copies = 26 cycles / 1,600 gates.
/// * Chi: 25×(init + NOT + NOR + XOR) = 100 cycles / 4,800 gates.
/// * Iota: RC mask init1 + init0 + init + XOR + init + copy-back
///   = 6 cycles / 128 gates.
const EXPECTED: [(&str, usize, usize); 5] =
    [("theta", 81, 3_520), ("rho", 762, 1_600), ("pi", 26, 1_600), ("chi", 100, 4_800), ("iota", 6, 128)];

#[test]
fn per_step_counts_hold_against_published_table() {
    let (_, stats) = build_keccak_round(geom()).expect("build round");
    for ((name, step), ((ename, ecyc, egates), (pname, pcyc, pgates))) in
        stats.steps().into_iter().zip(EXPECTED.into_iter().zip(PUBLISHED_STEP_TABLE))
    {
        assert_eq!(name, ename);
        assert_eq!(name, pname);
        assert_eq!(
            step,
            Sha3StepStats { cycles: ecyc, gates: egates },
            "{name}: emitted schedule drifted from the documented exact counts"
        );
        assert!(step.cycles <= pcyc, "{name}: {} cycles exceeds the published {pcyc}", step.cycles);
        assert!(step.gates <= pgates, "{name}: {} gates exceeds the published {pgates}", step.gates);
    }
    let total = stats.total();
    assert_eq!(total.cycles, 975);
    assert_eq!(total.gates, 11_648);
    // The acceptance bound: one round within the published 3,494 cycles.
    assert!(total.cycles <= PUBLISHED_ROUND_CYCLES);
    assert!(total.gates <= PUBLISHED_ROUND_GATES);
}

/// The reported stats are *accounting*, not measurement — tie them back to
/// the program they claim to describe: the single-round program's operation
/// count equals the stats' cycle total, and its stateful-gate count equals
/// the stats' gate total.
#[test]
fn round_stats_match_the_emitted_program() {
    let (program, stats) = build_keccak_round(geom()).expect("build round");
    let total = stats.total();
    assert_eq!(program.ops.len(), total.cycles, "every op is one cycle (inits included)");
    let gates: usize = program.ops.iter().map(|op| op.gate_count()).sum();
    assert_eq!(gates, total.gates);
}

/// Every round costs the same (the Iota mask split never degenerates:
/// every FIPS 202 round constant has both one- and zero-bits), so the full
/// permutation is exactly 24× the single-round schedule.
#[test]
fn full_permutation_is_24_identical_rounds() {
    let unit = build_keccak_f(geom()).expect("build keccak_f");
    let round = unit.round_stats.total();
    assert_eq!(round.cycles, 975);
    assert_eq!(unit.program.ops.len(), ROUNDS * round.cycles);
    let gates: usize = unit.program.ops.iter().map(|op| op.gate_count()).sum();
    assert_eq!(gates, ROUNDS * round.gates);
    assert!(round.cycles <= PUBLISHED_ROUND_CYCLES, "single-round latency must stay within the published budget");
}
