//! Wear- and reliability-aware serving under fault injection: stuck-at
//! faults struck mid-service must be absorbed by quarantine + remap —
//! every job still completes with values (and attributed metrics) bitwise
//! equal to a pristine fault-free bank — wear leveling must demonstrably
//! spread switch events across the array, and capacity exhaustion must
//! surface as the typed `RowQuarantined` error, never as silent corruption.

use partition_pim::coordinator::{PimService, RowQuarantined, ServiceConfig, WorkloadKind};
use partition_pim::crossbar::FaultMap;
use partition_pim::isa::models::ModelKind;

fn service(rows: usize, wear_leveling: bool) -> PimService {
    PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars: 1,
        rows,
        wear_leveling,
        ..Default::default()
    })
    .expect("service")
}

fn vectors(len: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s & 0xffff_ffff
    };
    ((0..len).map(|_| next()).collect(), (0..len).map(|_| next()).collect())
}

/// A stuck-at fault injected mid-service is fully transparent: every job
/// completes with values *and* attributed metrics (cycles, control bits,
/// switch events) bitwise equal to the same trace on a pristine fault-free
/// bank — placement invariance makes the quarantine + remap invisible.
///
/// Identical operands across jobs make the wear-leveling rotation exactly
/// predictable, so the faulty row is guaranteed to be hit (and remapped off)
/// deterministically.
#[test]
fn stuck_fault_mid_service_is_transparent_and_metric_exact() {
    let rows = 8;
    let jobs = 6;
    let a = vec![0x1234_5678u64; 6];
    let b = vec![0x0fed_cba9u64; 6];

    let run = |svc: &PimService, inject_after: Option<usize>| -> Vec<(Vec<u64>, u64, u64, u64)> {
        let mut out = Vec::new();
        for j in 0..jobs {
            let res = svc.submit(&a, &b).expect("submit").wait().expect("job must survive the stuck fault");
            out.push((res.try_scalars().expect("scalar job").to_vec(), res.sim_cycles, res.control_bits, res.switch_events));
            if inject_after == Some(j) {
                svc.inject_stuck(0, 0, true).expect("inject");
            }
        }
        out
    };

    let pristine = service(rows, true);
    let expect = run(&pristine, None);
    pristine.shutdown();

    let faulty = service(rows, true);
    let got = run(&faulty, Some(0));
    let wear = faulty.wear();
    let stats = faulty.shutdown();

    assert_eq!(got, expect, "faulty-bank results or metrics diverged from the pristine bank");
    for (vals, _, _, _) in &got {
        assert_eq!(vals, &a.iter().zip(&b).map(|(&x, &y)| x * y).collect::<Vec<u64>>());
    }
    assert_eq!(wear.quarantined_rows(), vec![0], "the stuck row must be quarantined exactly once");
    assert_eq!(stats.failed_jobs, 0);
    assert_eq!(stats.jobs, jobs as u64);
    assert!(stats.remapped_segments >= 1, "the segment caught on the stuck row must have been remapped");
    assert_eq!(stats.wear.quarantined_rows, 1);
}

/// Pipelined variant with distinct operands: jobs submitted before, during
/// and after the injection all complete with correct values — whichever
/// batches the stuck row happens to catch are remapped, and nothing leaks
/// corrupted data.
#[test]
fn pipelined_jobs_survive_stuck_fault_with_correct_values() {
    let svc = service(8, true);
    let mut pending = Vec::new();
    for j in 0..10u64 {
        let (a, b) = vectors(5, j + 1);
        let handle = svc.submit(&a, &b).expect("submit");
        pending.push((a, b, handle));
        if j == 4 {
            svc.inject_stuck(2, 1, true).expect("inject");
        }
    }
    for (j, (a, b, handle)) in pending.into_iter().enumerate() {
        let res = handle.wait().expect("job must survive the stuck fault");
        let vals = res.try_scalars().expect("scalar job");
        for i in 0..a.len() {
            assert_eq!(vals[i], a[i] * b[i], "job {j} element {i}");
        }
    }
    let stats = svc.shutdown();
    assert_eq!(stats.failed_jobs, 0);
    assert_eq!(stats.jobs, 10);
}

/// When quarantine eats the whole bank, the failure is typed: the job
/// resolves to `RowQuarantined` (matched with `downcast_ref`) carrying the
/// capacity arithmetic, after the bounded remap budget was actually spent.
#[test]
fn quarantine_exhaustion_fails_typed() {
    let svc = service(4, true);
    for row in 0..4 {
        svc.inject_stuck(row, 0, true).expect("inject");
    }
    let err = svc.submit(&[3], &[5]).expect("submit").wait().expect_err("no healthy rows can remain");
    let typed = err.downcast_ref::<RowQuarantined>().expect("typed RowQuarantined");
    assert_eq!(typed.rows_needed, 1);
    assert_eq!(typed.healthy_rows, 0);
    assert_eq!(typed.remaps, 3, "the default remap budget must be spent before giving up");
    let stats = svc.shutdown();
    assert_eq!(stats.failed_jobs, 1);
    assert_eq!(stats.remapped_segments, 3);
    assert_eq!(stats.wear.quarantined_rows, 4);
}

/// The ablation pair: with leveling off every batch front-packs the same
/// rows and wear concentrates; with leveling on the same trace spreads
/// across the whole array — lower peak wear and a lower Gini coefficient.
#[test]
fn wear_leveling_spreads_wear() {
    let rows = 32;
    let a = vec![0xdead_beefu64; 4];
    let b = vec![0x0bad_cafeu64; 4];
    let trace = |svc: &PimService| {
        for _ in 0..64 {
            svc.submit(&a, &b).expect("submit").wait().expect("job");
        }
        svc.wear()
    };

    let packed_svc = service(rows, false);
    let packed = trace(&packed_svc);
    packed_svc.shutdown();

    let leveled_svc = service(rows, true);
    let leveled = trace(&leveled_svc);
    leveled_svc.shutdown();

    assert_eq!(packed.total_wear(), leveled.total_wear(), "leveling relocates switches, it must not change their count");
    assert!(packed.max_wear() > 0 && leveled.max_wear() > 0);
    // Row-parallel init cycles wear every row a little each batch, so the
    // contrast is bounded by the data-dependent share of switching — assert
    // the ordering, not a fixed factor.
    assert!(
        packed.max_wear() > leveled.max_wear(),
        "front-packing must concentrate wear (packed max {}, leveled max {})",
        packed.max_wear(),
        leveled.max_wear()
    );
    assert!(
        leveled.gini() < packed.gini(),
        "leveling must flatten the wear distribution (packed gini {:.3}, leveled gini {:.3})",
        packed.gini(),
        leveled.gini()
    );
}

/// SHA-3 wear accumulation: Keccak-f jobs drive the bank's persistent wear
/// map exactly like the arithmetic workloads — switch events accumulate
/// across jobs, wear leveling spreads them over the array rather than
/// hammering the front rows, and every permuted state stays bitwise-exact
/// while the map fills (wear accounting must never perturb values).
#[test]
fn sha3_jobs_accumulate_and_level_wear() {
    use partition_pim::algorithms::sha3;

    let rows = 8;
    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Sha3,
        model: ModelKind::Minimal,
        n_crossbars: 1,
        rows,
        wear_leveling: true,
        ..Default::default()
    })
    .expect("sha3 service");

    let mut s = 0x5851f42d4c957f2du64;
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s
    };
    let mut totals = Vec::new();
    for _ in 0..6 {
        // Half-occupancy jobs: leveling has empty rows to rotate onto.
        let states: Vec<[u64; 25]> = (0..rows / 2)
            .map(|_| {
                let mut st = [0u64; 25];
                for lane in st.iter_mut() {
                    *lane = next();
                }
                st
            })
            .collect();
        let res = svc.submit_job(WorkloadKind::Sha3, partition_pim::coordinator::Payload::States(states.clone()))
            .expect("submit")
            .wait()
            .expect("sha3 job");
        let got = res.try_states().expect("sha3 values");
        for (i, st) in states.iter().enumerate() {
            let mut want = *st;
            sha3::keccak_f_sw(&mut want);
            assert_eq!(got[i], want, "state {i} must stay exact while wear accumulates");
        }
        assert!(res.switch_events > 0, "a 24-round permutation must flip memristors");
        totals.push(svc.wear().total_wear());
    }
    // Wear accumulates monotonically across jobs...
    assert!(totals.windows(2).all(|w| w[0] < w[1]), "each sha3 job must add wear: {totals:?}");
    let wear = svc.wear();
    // ...and leveling rotated the half-occupancy batches across the whole
    // array: every row saw traffic.
    assert!(wear.quarantined_rows().is_empty());
    for row in 0..rows {
        assert!(wear.wear(row) > 0, "row {row} must have seen sha3 traffic (leveling + row-parallel inits)");
    }
    let stats = svc.shutdown();
    assert_eq!(stats.failed_jobs, 0);
    assert_eq!(stats.jobs, 6);
}

/// `FaultMap::random` is a pure function of its arguments: identical seeds
/// reproduce the identical fault population (the property every randomized
/// reliability experiment in the repo leans on), and different seeds do not.
#[test]
fn faultmap_random_is_deterministic() {
    let a = FaultMap::random(64, 256, 0.01, 42);
    let b = FaultMap::random(64, 256, 0.01, 42);
    assert_eq!(a.faults, b.faults);
    assert!(!a.faults.is_empty(), "a 1% rate over 16384 cells must produce faults");

    let c = FaultMap::random(64, 256, 0.01, 43);
    assert_ne!(a.faults, c.faults, "different seeds must draw different fault populations");

    // Seed 0 is clamped, not degenerate.
    let d = FaultMap::random(64, 256, 0.01, 0);
    let e = FaultMap::random(64, 256, 0.01, 1);
    assert_eq!(d.faults, e.faults);
}
