//! Integration: the full L3 service under every model — correctness of
//! batched vector arithmetic, metric accounting, and the Figure-6 orderings
//! observed end-to-end through the coordinator (not just program stats).

use partition_pim::coordinator::{PimService, ServiceConfig, WorkloadKind};
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::encode::message_bits;
use partition_pim::isa::models::ModelKind;

fn vectors(len: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
    let mut s = seed;
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s & 0xffff_ffff
    };
    ((0..len).map(|_| next()).collect(), (0..len).map(|_| next()).collect())
}

#[test]
fn multiply_service_all_models() {
    for model in ModelKind::ALL {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model,
            n_crossbars: 3,
            rows: 16,
            ..Default::default()
        })
        .unwrap_or_else(|e| panic!("{}: {e}", model.name()));
        let (a, b) = vectors(100, 42);
        let res = svc.submit(&a, &b).expect("submit").wait().expect("wait");
        for i in 0..100 {
            assert_eq!(res.scalars()[i], a[i] * b[i], "{} element {i}", model.name());
        }
        let stats = svc.shutdown();
        assert_eq!(stats.elements, 100);
        assert_eq!(stats.chunks, 7); // ceil(100/16)
        assert!(stats.metrics.control_bits > 0);
    }
}

#[test]
fn add_service_all_models() {
    for model in ModelKind::ALL {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Add32,
            model,
            n_crossbars: 2,
            rows: 8,
            ..Default::default()
        })
        .unwrap_or_else(|e| panic!("{}: {e}", model.name()));
        let (a, b) = vectors(40, 7);
        let res = svc.submit(&a, &b).expect("submit").wait().expect("wait");
        for i in 0..40 {
            assert_eq!(res.scalars()[i], a[i] + b[i], "{} element {i}", model.name());
        }
        svc.shutdown();
    }
}

/// End-to-end Figure 6 orderings, observed through the metered service:
/// latency unlimited <= standard <= minimal << baseline, and control
/// traffic per cycle matching each model's wire format.
#[test]
fn end_to_end_figure6_orderings() {
    let mut cycles = std::collections::HashMap::new();
    let mut per_cycle_bits = std::collections::HashMap::new();
    for model in ModelKind::ALL {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model,
            n_crossbars: 1,
            rows: 4,
            ..Default::default()
        })
        .expect("service");
        let (a, b) = vectors(4, 1234);
        let res = svc.submit(&a, &b).expect("submit").wait().expect("wait");
        cycles.insert(model, res.sim_cycles);
        let stats = svc.shutdown();
        // Gate messages dominate; compare measured bits/gate-cycle to the format.
        let gate_bits = stats.metrics.control_bits
            - stats.metrics.init_cycles * 30; // init writes charged 3*log2(1024) = 30 bits
        per_cycle_bits.insert(model, gate_bits as f64 / stats.metrics.gate_cycles as f64);
    }
    assert!(cycles[&ModelKind::Unlimited] <= cycles[&ModelKind::Standard]);
    assert!(cycles[&ModelKind::Standard] <= cycles[&ModelKind::Minimal]);
    assert!(cycles[&ModelKind::Baseline] > 5 * cycles[&ModelKind::Minimal]);

    let geom = Geometry::paper(4).unwrap();
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let expect = message_bits(model, &geom) as f64;
        let got = per_cycle_bits[&model];
        assert!((got - expect).abs() < 1e-9, "{}: {got} bits/cycle != {expect}", model.name());
    }
}

#[test]
fn many_small_jobs_round_robin() {
    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars: 4,
        rows: 8,
        ..Default::default()
    })
    .expect("service");
    for j in 0..20u64 {
        let (a, b) = vectors(3, j + 1);
        let res = svc.submit(&a, &b).expect("submit").wait().expect("wait");
        for i in 0..3 {
            assert_eq!(res.scalars()[i], a[i] * b[i]);
        }
    }
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, 20);
    assert_eq!(stats.elements, 60);
}

/// Sort jobs through the service, every model: each row's 16-element vector
/// comes back sorted, and the model ordering holds for sort latency too.
/// `submit_sort` resolves to the same unified `JobResult` as `submit`.
#[test]
fn sort_service_all_models() {
    let mut cycles_by_model = std::collections::HashMap::new();
    for model in ModelKind::ALL {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Sort16,
            model,
            n_crossbars: 2,
            rows: 4,
            ..Default::default()
        })
        .unwrap_or_else(|e| panic!("{}: {e}", model.name()));
        let mut seed = 31u64;
        let rows: Vec<Vec<u64>> = (0..10)
            .map(|_| {
                (0..16)
                    .map(|_| {
                        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                        (seed >> 40) % 64
                    })
                    .collect()
            })
            .collect();
        let res = svc.submit_sort(&rows).expect("submit_sort").wait().expect("wait");
        for (i, row) in rows.iter().enumerate() {
            let mut expect = row.clone();
            expect.sort_unstable();
            assert_eq!(res.rows()[i], expect, "{} row {i}", model.name());
        }
        assert!(res.control_bits > 0);
        cycles_by_model.insert(model, res.sim_cycles);
        svc.shutdown();
    }
    assert!(cycles_by_model[&ModelKind::Unlimited] <= cycles_by_model[&ModelKind::Standard]);
    assert!(cycles_by_model[&ModelKind::Standard] <= cycles_by_model[&ModelKind::Minimal]);
    assert!(cycles_by_model[&ModelKind::Baseline] > cycles_by_model[&ModelKind::Minimal]);
}

/// Mixing job types is rejected cleanly, in both directions.
#[test]
fn wrong_job_type_rejected() {
    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars: 1,
        rows: 4,
        ..Default::default()
    })
    .expect("service");
    assert!(svc.submit_sort(&[vec![1; 16]]).is_err());
    svc.shutdown();

    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Sort16,
        model: ModelKind::Minimal,
        n_crossbars: 1,
        rows: 4,
        ..Default::default()
    })
    .expect("service");
    assert!(svc.submit(&[1, 2], &[3, 4]).is_err());
    svc.shutdown();
}
