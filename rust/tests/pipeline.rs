//! Integration: the full control pipeline — abstract operation → controller
//! encode → wire bits → periphery decode → reconstructed gates → crossbar —
//! must be an identity on semantics for every model, and must reject
//! malformed traffic without corrupting state.

use partition_pim::backend::{ExecPipeline, PimBackend};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::encode::{decode, encode, message_bits, BitVec};
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::operation::{GateOp, Operation};
use partition_pim::periphery;

/// Deterministic xorshift for reproducible randomized tests.
struct Rng(u64);
impl Rng {
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

/// Generate a random operation legal under `model`.
fn random_legal_op(rng: &mut Rng, geom: &Geometry, model: ModelKind) -> Operation {
    let m = geom.m();
    loop {
        let candidate = match model {
            ModelKind::Baseline => {
                let a = rng.below(geom.n);
                let b = rng.below(geom.n);
                let mut o = rng.below(geom.n);
                while o == a || o == b {
                    o = rng.below(geom.n);
                }
                Operation::serial(if rng.below(4) == 0 { GateOp::not(a, o) } else { GateOp::nor(a, b, o) })
            }
            _ => {
                // Random periodic pattern (minimal-legal => legal everywhere).
                let d = rng.below(geom.k.min(4));
                let t = d + 1 + rng.below(3);
                let p_start = rng.below(geom.k - d);
                let count = 1 + rng.below(((geom.k - d - p_start - 1) / t.max(1)).max(1));
                let ia = rng.below(m);
                let mut ib = rng.below(m);
                let mut io = rng.below(m);
                while io == ia || io == ib {
                    io = rng.below(m);
                }
                if rng.below(4) == 0 {
                    ib = ia; // NOT
                }
                let gates: Vec<GateOp> = (0..count)
                    .map(|j| {
                        let p = p_start + j * t;
                        let g = if ia == ib {
                            GateOp::not(geom.col(p, ia), geom.col(p + d, io))
                        } else {
                            GateOp::nor(geom.col(p, ia), geom.col(p, ib), geom.col(p + d, io))
                        };
                        g
                    })
                    .collect();
                Operation::Gates(gates)
            }
        };
        if model.supports(&candidate, geom, GateSet::NotNor) {
            return candidate;
        }
    }
}

#[test]
fn randomized_roundtrip_all_models() {
    let geom = Geometry::new(512, 16, 64).unwrap();
    let mut rng = Rng(0xfeedface);
    for model in ModelKind::ALL {
        for trial in 0..200 {
            let op = random_legal_op(&mut rng, &geom, model);
            let bits = encode(model, &op, &geom)
                .unwrap_or_else(|e| panic!("{} trial {trial}: encode failed: {e}\n{op:?}", model.name()));
            assert_eq!(bits.len(), message_bits(model, &geom));
            let msg = decode(model, &bits, &geom).expect("decode");
            let rec = periphery::reconstruct(&msg, &geom).expect("reconstruct");
            assert_eq!(rec.normalized(), op.normalized(), "{} trial {trial}", model.name());
        }
    }
}

#[test]
fn randomized_execution_equivalence() {
    let geom = Geometry::new(512, 16, 96).unwrap();
    let mut rng = Rng(0xdecade);
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let mut direct = Crossbar::new(geom, GateSet::NotNor);
        direct.state.fill_random(17);
        let mut wired = direct.clone();
        let mut pipe = ExecPipeline::wire(model, &mut wired);
        for _ in 0..100 {
            let op = random_legal_op(&mut rng, &geom, model);
            direct.execute(&op).expect("direct");
            pipe.run_op(&op).expect("message");
        }
        let stats = pipe.stats();
        drop(pipe);
        assert_eq!(direct.state, wired.state, "{} diverged", model.name());
        assert_eq!(stats.messages, 100);
        assert_eq!(stats.control_bits, 100 * message_bits(model, &geom) as u64);
    }
}

/// Bit-flip fuzzing: corrupted control messages must either decode to a
/// *valid* operation or be rejected — never panic, never execute an
/// inconsistent half-gate combination.
#[test]
fn corrupted_messages_never_panic() {
    let geom = Geometry::new(512, 16, 8).unwrap();
    let mut rng = Rng(0xc0ffee);
    for model in ModelKind::ALL {
        for _ in 0..300 {
            let op = random_legal_op(&mut rng, &geom, model);
            let bits = encode(model, &op, &geom).expect("encode");
            // Flip 1-3 random bits.
            let mut corrupted = bits.clone();
            for _ in 0..1 + rng.below(3) {
                corrupted.flip(rng.below(corrupted.len()));
            }
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            xb.state.fill_random(5);
            // Either executes a (different but physically valid) op, or errors.
            let _ = ExecPipeline::wire(model, &mut xb).run_wire(&corrupted);
        }
    }
}

#[test]
fn truncated_messages_rejected() {
    let geom = Geometry::new(512, 16, 8).unwrap();
    let op = Operation::serial(GateOp::nor(0, 1, 40));
    for model in ModelKind::ALL {
        let bits = encode(model, &op, &geom).expect("encode");
        let mut short = BitVec::new();
        for i in 0..bits.len() - 1 {
            short.push_bit(bits.get(i));
        }
        assert!(decode(model, &short, &geom).is_err(), "{}", model.name());
    }
}

/// Cross-model agreement: the same minimal-legal operation must execute to
/// the same state through all four wire formats.
#[test]
fn cross_model_state_agreement() {
    let geom = Geometry::new(512, 16, 64).unwrap();
    let mut rng = Rng(0xabcdef);
    for _ in 0..50 {
        let op = random_legal_op(&mut rng, &geom, ModelKind::Minimal);
        let mut reference: Option<partition_pim::crossbar::state::BitMatrix> = None;
        for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            xb.state.fill_random(11);
            ExecPipeline::wire(model, &mut xb).run_op(&op).expect("execute");
            match &reference {
                None => reference = Some(xb.state.clone()),
                Some(r) => assert_eq!(&xb.state, r, "{} disagrees", model.name()),
            }
        }
    }
}

/// Geometry sweep: the codecs and periphery must work at every partition
/// count, and the wire-format lengths must follow the paper's formulas as
/// k scales (the control/flexibility trade-off curve).
#[test]
fn geometry_sweep_roundtrips() {
    let mut rng = Rng(0xbead);
    for (n, k) in [(64usize, 2usize), (64, 4), (256, 4), (256, 32), (1024, 2), (1024, 64), (4096, 32)] {
        let geom = Geometry::new(n, k, 8).unwrap();
        for model in ModelKind::ALL {
            // Formula consistency.
            let (ln, lk, lm) = (geom.log2_n(), geom.log2_k(), geom.log2_m());
            let expect = match model {
                ModelKind::Baseline => 3 * ln,
                ModelKind::Unlimited => 3 * k * lm + 3 * k + (k - 1),
                ModelKind::Standard => 3 * lm + (2 * k - 1) + 1,
                ModelKind::Minimal => 3 * lm + 3 * lk + lk + 1,
            };
            assert_eq!(message_bits(model, &geom), expect, "{} n={n} k={k}", model.name());
            // Round-trip a batch of random legal ops.
            for _ in 0..20 {
                let op = random_legal_op(&mut rng, &geom, model);
                let bits = encode(model, &op, &geom).expect("encode");
                let rec = periphery::reconstruct(&decode(model, &bits, &geom).expect("decode"), &geom).expect("reconstruct");
                assert_eq!(rec.normalized(), op.normalized(), "{} n={n} k={k}", model.name());
            }
        }
    }
}

/// The minimal model's control advantage grows with k while standard's
/// shrinks relative to unlimited — the scaling behind Figure 6(b).
#[test]
fn control_overhead_scaling_with_k() {
    let mut prev_ratio = 0.0;
    for k in [2usize, 8, 32] {
        let geom = Geometry::new(1024, k, 1).unwrap();
        let unl = message_bits(ModelKind::Unlimited, &geom) as f64;
        let min = message_bits(ModelKind::Minimal, &geom) as f64;
        let ratio = unl / min;
        assert!(ratio > prev_ratio, "unlimited/minimal ratio must grow with k");
        prev_ratio = ratio;
    }
}
