//! Fleet failure-domain and policy tests: mixed traffic (mul + add + sort
//! + sha3) routed with zero workload-mismatch rejections, a bank killed
//! mid-trace with every accepted job still completing (or failing cleanly
//! — no wedge), hot-spare promotion, typed admission-control backpressure,
//! the unified `WorkloadMismatch` error in both directions, `wait_timeout`
//! leaving handles reusable, pristine-vs-reused-fleet metric equality, and
//! elastic spawn/retire.

use partition_pim::algorithms::sha3;
use partition_pim::coordinator::worker::{SORT_BITS, SORT_ELEMS};
use partition_pim::coordinator::{
    BankState, ElasticPolicy, FleetConfig, JobShape, Overloaded, PimFleet, PimService, ServiceConfig, WorkloadKind, WorkloadMismatch,
};
use partition_pim::isa::models::ModelKind;
use std::time::Duration;

const MIX: [WorkloadKind; 4] = [WorkloadKind::Mul32, WorkloadKind::Add32, WorkloadKind::Sort16, WorkloadKind::Sha3];

fn vectors(len: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s & 0xffff_ffff
    };
    ((0..len).map(|_| next()).collect(), (0..len).map(|_| next()).collect())
}

fn sort_rows(n_rows: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(7);
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s & ((1 << SORT_BITS) - 1)
    };
    (0..n_rows).map(|_| (0..SORT_ELEMS).map(|_| next()).collect()).collect()
}

fn keccak_states(n_rows: usize, seed: u64) -> Vec<[u64; 25]> {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(13);
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s
    };
    (0..n_rows)
        .map(|_| {
            let mut st = [0u64; 25];
            for lane in st.iter_mut() {
                *lane = next();
            }
            st
        })
        .collect()
}

fn base_config(rows: usize) -> ServiceConfig {
    ServiceConfig { model: ModelKind::Minimal, n_crossbars: 2, rows, ..Default::default() }
}

fn mixed_fleet(n_banks: usize, rows: usize) -> PimFleet {
    PimFleet::start(FleetConfig::mixed(&MIX, n_banks, base_config(rows)).expect("config")).expect("fleet")
}

/// The headline acceptance property: a mixed mul + add + sort + sha3 trace
/// served by one fleet completes with *zero* jobs rejected for workload
/// mismatch (or anything else) — routing by shape compatibility works
/// end-to-end, and every value is exact (sha3 states bitwise-equal the
/// software Keccak-f oracle).
#[test]
fn mixed_trace_completes_with_zero_mismatch_rejections() {
    let fleet = mixed_fleet(4, 8);
    let client = fleet.client();
    let n_jobs = 20usize;
    let mut pending = Vec::new();
    for j in 0..n_jobs {
        let kind = MIX[j % MIX.len()];
        match kind.shape() {
            JobShape::ElementWise => {
                let (a, b) = vectors(10 + j, j as u64);
                let handle = client.submit(kind, &a, &b).expect("mixed submit must never be rejected");
                pending.push((kind, Some((a, b)), None, None, handle));
            }
            JobShape::RowVectors => {
                let data = sort_rows(6, j as u64);
                let handle = client.submit_sort(&data).expect("sort submit must never be rejected");
                pending.push((kind, None, Some(data), None, handle));
            }
            JobShape::KeccakState => {
                let states = keccak_states(4, j as u64);
                let handle = client.submit_sha3(&states).expect("sha3 submit must never be rejected");
                pending.push((kind, None, None, Some(states), handle));
            }
        }
    }
    for (kind, pairs, rows_data, states, handle) in pending {
        let res = handle.wait().expect("mixed job");
        match kind.shape() {
            JobShape::ElementWise => {
                let (a, b) = pairs.expect("element-wise job keeps its operands");
                for i in 0..a.len() {
                    let want = if kind == WorkloadKind::Mul32 { a[i] * b[i] } else { a[i] + b[i] };
                    assert_eq!(res.scalars()[i], want, "{} element {i}", kind.name());
                }
            }
            JobShape::RowVectors => {
                for (i, row) in rows_data.expect("sort job keeps its operands").iter().enumerate() {
                    let mut want = row.clone();
                    want.sort_unstable();
                    assert_eq!(res.rows()[i], want, "sort row {i}");
                }
            }
            JobShape::KeccakState => {
                for (i, st) in states.expect("sha3 job keeps its operands").iter().enumerate() {
                    let mut want = *st;
                    sha3::keccak_f_sw(&mut want);
                    assert_eq!(res.try_states().expect("sha3 values")[i], want, "sha3 state {i} vs the software oracle");
                }
            }
        }
    }
    let stats = fleet.shutdown();
    assert_eq!(stats.aggregate.jobs, n_jobs as u64);
    assert_eq!(stats.aggregate.failed_jobs, 0);
    assert_eq!(stats.counters.routed, n_jobs as u64);
    assert_eq!(stats.counters.rejected_no_bank, 0, "no job may be rejected for workload mismatch");
    assert_eq!(stats.counters.rejected_overloaded, 0);
    assert_eq!(stats.counters.reroutes, 0);
}

/// Satellite regression: both wrong-workload directions resolve to the one
/// typed `WorkloadMismatch` error, with the service's kind and the
/// submission's shape populated.
#[test]
fn workload_mismatch_is_typed_in_both_directions() {
    let mul = PimService::start(base_config(8)).expect("mul service");
    let err = mul.submit_sort(&sort_rows(2, 1)).expect_err("sort job on a mul bank must be rejected");
    let m = err.downcast_ref::<WorkloadMismatch>().expect("typed WorkloadMismatch (sort-on-mul)");
    assert_eq!(m.service, WorkloadKind::Mul32);
    assert_eq!(m.submitted, JobShape::RowVectors);
    mul.shutdown();

    let sort = PimService::start(ServiceConfig { kind: WorkloadKind::Sort16, ..base_config(8) }).expect("sort service");
    let err = sort.submit(&[1, 2], &[3, 4]).expect_err("element-wise job on a sort bank must be rejected");
    let m = err.downcast_ref::<WorkloadMismatch>().expect("typed WorkloadMismatch (pairs-on-sort)");
    assert_eq!(m.service, WorkloadKind::Sort16);
    assert_eq!(m.submitted, JobShape::ElementWise);
    sort.shutdown();
}

/// Satellite: a timed-out `wait_timeout` leaves the handle reusable — the
/// same handle still delivers the exact result afterwards. The job is held
/// in flight deterministically by a long coalescer linger window.
#[test]
fn wait_timeout_leaves_handle_reusable() {
    let svc = PimService::start(ServiceConfig { linger: Duration::from_millis(400), ..base_config(8) }).expect("service");
    let (a, b) = vectors(2, 42);
    let handle = svc.submit(&a, &b).expect("submit");
    // The 2-element job lingers in the underfull batch for ~400ms, so a
    // 10ms wait must time out...
    assert!(handle.wait_timeout(Duration::from_millis(10)).is_none(), "job should still be lingering");
    // ...and the handle must still deliver the result once the window ends.
    let res = handle.wait_timeout(Duration::from_secs(20)).expect("job must complete after the linger window").expect("job result");
    assert_eq!(res.scalars(), &[a[0] * b[0], a[1] * b[1]]);
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, 1);
    assert_eq!(stats.failed_jobs, 0);
}

/// Tentpole failure domain: a bank killed mid-trace. Every accepted job
/// must complete (rerouted to the promoted hot spare) or fail cleanly —
/// no handle may hang. With a spare present and reroutes enabled, all of
/// them in fact complete, and the lifecycle counters record the death and
/// the promotion.
#[test]
fn killed_bank_mid_trace_jobs_finish_via_hot_spare() {
    // One mul bank + one hot spare; a long linger holds submitted jobs in
    // the coalescer, so the kill deterministically catches them in flight.
    let mut cfg = FleetConfig { banks: vec![base_config(8)], spare_slots: 1, ..Default::default() };
    cfg.banks[0].linger = Duration::from_millis(300);
    let fleet = PimFleet::start(cfg).expect("fleet");
    let client = fleet.client();
    let mut pending = Vec::new();
    for j in 0..3 {
        let (a, b) = vectors(2, 100 + j);
        let handle = client.submit(WorkloadKind::Mul32, &a, &b).expect("submit");
        pending.push((a, b, handle));
    }
    fleet.kill_bank(0).expect("kill bank 0");
    for (a, b, mut handle) in pending {
        // Bounded wait: a wedge fails the test instead of hanging it.
        let res = handle
            .wait_timeout(Duration::from_secs(60))
            .expect("no fleet job may wedge after a bank death")
            .expect("job must complete via the promoted spare");
        for i in 0..a.len() {
            assert_eq!(res.scalars()[i], a[i] * b[i]);
        }
    }
    // New submissions after the death land on the promoted spare.
    let (a, b) = vectors(3, 999);
    let res = client.submit(WorkloadKind::Mul32, &a, &b).expect("submit after death").wait().expect("spare serves new jobs");
    assert_eq!(res.scalars()[0], a[0] * b[0]);
    let stats = fleet.shutdown();
    assert_eq!(stats.counters.banks_dead, 1);
    assert_eq!(stats.counters.spares_promoted, 1);
    assert!(stats.counters.reroutes >= 1, "at least one in-flight job must have rerouted");
    assert_eq!(stats.aggregate.jobs, 4, "every accepted job completed exactly once");
    let dead = stats.banks.iter().filter(|b| b.state == BankState::Dead).count();
    assert_eq!(dead, 1);
}

/// A larger mixed trace (sha3 included) with a mid-trace bank kill on a
/// fleet that has a second bank per workload: jobs reroute onto the
/// surviving peer (no spare needed), nothing wedges, and the fleet's
/// aggregate accounts for every accepted job as either completed or
/// cleanly failed.
#[test]
fn kill_bank_mid_mixed_trace_no_wedge() {
    // 8 banks over a 4-workload mix = two banks per workload.
    let fleet = mixed_fleet(8, 8);
    let client = fleet.client();
    let n_jobs = 24usize;
    let mut accepted = Vec::new();
    for j in 0..n_jobs {
        let kind = MIX[j % MIX.len()];
        let handle = match kind.shape() {
            JobShape::ElementWise => {
                let (a, b) = vectors(16, j as u64);
                client.submit(kind, &a, &b).expect("submit")
            }
            JobShape::RowVectors => client.submit_sort(&sort_rows(4, j as u64)).expect("submit_sort"),
            JobShape::KeccakState => client.submit_sha3(&keccak_states(4, j as u64)).expect("submit_sha3"),
        };
        accepted.push(handle);
        if j == n_jobs / 2 {
            fleet.kill_bank(0).expect("kill bank 0 (a mul bank)");
        }
    }
    let (mut completed, mut failed) = (0u64, 0u64);
    for mut handle in accepted {
        match handle.wait_timeout(Duration::from_secs(60)).expect("no fleet job may wedge after a bank death") {
            Ok(_) => completed += 1,
            Err(_) => failed += 1,
        }
    }
    assert_eq!(completed + failed, n_jobs as u64, "every accepted job resolves");
    // With a surviving mul bank to reroute onto, nothing should fail.
    assert_eq!(failed, 0, "in-flight jobs reroute onto the surviving peer bank");
    let stats = fleet.shutdown();
    assert_eq!(stats.counters.banks_dead, 1);
    assert_eq!(stats.aggregate.jobs, completed);
}

/// Admission control: with the per-bank bound reached, `submit` fails fast
/// with the typed `Overloaded` error — and clears once the queue drains.
#[test]
fn admission_control_rejects_with_typed_overloaded() {
    let mut cfg = FleetConfig { banks: vec![base_config(8)], ..Default::default() };
    cfg.banks[0].linger = Duration::from_millis(300);
    cfg.max_pending_per_bank = 2;
    let fleet = PimFleet::start(cfg).expect("fleet");
    let client = fleet.client();
    // Two 1-element jobs linger in the coalescer: the bank is at its bound.
    let h1 = client.submit(WorkloadKind::Mul32, &[3], &[5]).expect("first submit");
    let h2 = client.submit(WorkloadKind::Mul32, &[4], &[6]).expect("second submit");
    let err = client.submit(WorkloadKind::Mul32, &[7], &[8]).expect_err("third submit must hit the admission bound");
    let o = err.downcast_ref::<Overloaded>().expect("typed Overloaded");
    assert_eq!(o.kind, WorkloadKind::Mul32);
    assert_eq!(o.limit, 2);
    assert!(o.pending >= 2, "rejection reports the observed queue depth");
    // Backpressure is not a wedge: the queued jobs complete...
    assert_eq!(h1.wait().expect("first job").scalars(), &[15]);
    assert_eq!(h2.wait().expect("second job").scalars(), &[24]);
    // ...and the bound clears with the queue.
    let h3 = client.submit(WorkloadKind::Mul32, &[7], &[8]).expect("admission clears once the queue drains");
    assert_eq!(h3.wait().expect("third job").scalars(), &[56]);
    let stats = fleet.shutdown();
    assert_eq!(stats.counters.rejected_overloaded, 1);
    assert_eq!(stats.aggregate.jobs, 3);
}

/// Metric-equality property lifted to the fleet tier: the same sequential
/// trace on a pristine fleet and on a fleet that has already served (and
/// lost a bank of) an earlier trace reports identical per-job values and
/// metrics — serving history, coalescing state and bank identity must not
/// leak into per-job attribution.
#[test]
fn pristine_vs_reused_fleet_metric_equality() {
    let trace = |fleet: &PimFleet, salt: u64| -> Vec<(Vec<u64>, u64, u64, u64)> {
        let client = fleet.client();
        let mut out = Vec::new();
        for j in 0..6u64 {
            let (a, b) = vectors(12, 1000 + salt + j);
            // Sequential submit + wait: no co-batching, deterministic
            // least-loaded routing (all banks idle each time).
            let res = client.submit(WorkloadKind::Mul32, &a, &b).expect("submit").wait().expect("job");
            out.push((res.scalars().to_vec(), res.sim_cycles, res.control_bits, res.switch_events));
        }
        out
    };

    let pristine = mixed_fleet(3, 8);
    let want = trace(&pristine, 0);
    pristine.shutdown();

    let reused = mixed_fleet(3, 8);
    // Dirty the fleet: serve an unrelated warmup trace first.
    let _ = trace(&reused, 777);
    let got = trace(&reused, 0);
    reused.shutdown();
    assert_eq!(want, got, "per-job values and metrics must not depend on fleet history");
}

/// Elastic lifecycle: a burst of arrivals spawns extra banks for the hot
/// workload (warm from the compile cache), and once the window drains the
/// surplus banks retire — never below one bank per served workload.
#[test]
fn elastic_spawns_on_burst_and_retires_when_idle() {
    let cfg = FleetConfig {
        banks: vec![base_config(8)],
        elastic: ElasticPolicy { enabled: true, window: Duration::from_secs(2), jobs_per_bank_window: 4, max_banks: 4 },
        ..Default::default()
    };
    let fleet = PimFleet::start(cfg).expect("fleet");
    let client = fleet.client();
    for j in 0..12u64 {
        let (a, b) = vectors(4, j);
        let res = client.submit(WorkloadKind::Mul32, &a, &b).expect("submit").wait().expect("job");
        assert_eq!(res.scalars()[0], a[0] * b[0]);
    }
    // 12 arrivals inside the window at 4 jobs-per-bank-window wants 3 banks
    // (fewer only if the trace outran the window on a slow machine — spawn
    // at least once either way).
    let burst_banks = fleet.active_banks();
    assert!(burst_banks >= 2, "the burst must spawn at least one extra bank (got {burst_banks})");
    // Let the arrival window drain, then autoscale back down.
    std::thread::sleep(Duration::from_millis(2200));
    fleet.autoscale();
    assert_eq!(fleet.active_banks(), 1, "idle surplus banks retire, the workload keeps one bank");
    let stats = fleet.shutdown();
    assert!(stats.counters.banks_spawned as usize >= burst_banks - 1);
    assert_eq!(stats.counters.banks_spawned, stats.counters.banks_retired, "every elastic spawn is eventually retired");
    assert_eq!(stats.aggregate.jobs, 12);
    assert_eq!(stats.aggregate.failed_jobs, 0);
}
