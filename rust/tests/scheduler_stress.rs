//! Scheduler stress and fault-isolation tests: many jobs genuinely in
//! flight across a small bank, malformed jobs failing in isolation,
//! crashed/killed workers whose work requeues to the survivors, and the
//! coalescer packing small jobs into shared row-batches.

use partition_pim::coordinator::{PimService, ServiceConfig, WorkloadKind};
use partition_pim::isa::models::ModelKind;
use std::time::Duration;

fn vectors(len: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    let mut next = move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s & 0xffff_ffff
    };
    ((0..len).map(|_| next()).collect(), (0..len).map(|_| next()).collect())
}

fn mul_service(n_crossbars: usize, rows: usize) -> PimService {
    PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars,
        rows,
        ..Default::default()
    })
    .expect("service")
}

/// Many mixed-size jobs in flight at once; results are checked element-wise
/// and the aggregate statistics are exact. Handles are awaited in *reverse*
/// submission order, so early jobs are still pending while later ones are
/// already being consumed — several jobs genuinely overlap.
#[test]
fn stress_mixed_jobs_in_flight() {
    let rows = 8usize;
    let svc = mul_service(3, rows);
    let sizes = [1usize, 5, 8, 9, 17, 24, 31, 40, 64, 70, 3, 12];
    let mut pending = Vec::new();
    for (j, &len) in sizes.iter().enumerate() {
        let (a, b) = vectors(len, j as u64);
        let handle = svc.submit(&a, &b).expect("submit");
        pending.push((a, b, handle));
    }
    for (a, b, handle) in pending.into_iter().rev() {
        let res = handle.wait().expect("wait");
        for i in 0..a.len() {
            assert_eq!(res.scalars()[i], a[i] * b[i], "job {} element {i}", res.id);
        }
        assert!(res.sim_cycles > 0 && res.control_bits > 0);
    }
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, sizes.len() as u64);
    assert_eq!(stats.failed_jobs, 0);
    assert_eq!(stats.elements, sizes.iter().sum::<usize>() as u64);
    assert_eq!(stats.chunks, sizes.iter().map(|s| s.div_ceil(rows)).sum::<usize>() as u64);
}

/// Multiple client threads drive one bank through cloned [`PimClient`]s —
/// the multi-tenant scenario. Every job's results are exact and the
/// aggregate counters add up.
#[test]
fn concurrent_clients_from_threads() {
    let svc = mul_service(4, 8);
    let n_threads = 4usize;
    let jobs_per_thread = 5usize;
    let len = 21usize;
    let mut joins = Vec::new();
    for t in 0..n_threads {
        let client = svc.client();
        joins.push(std::thread::spawn(move || {
            for j in 0..jobs_per_thread {
                let (a, b) = vectors(len, (t * 1000 + j) as u64);
                let res = client.submit(&a, &b).expect("submit").wait().expect("wait");
                for i in 0..len {
                    assert_eq!(res.scalars()[i], a[i] * b[i], "thread {t} job {j} element {i}");
                }
            }
        }));
    }
    for j in joins {
        j.join().expect("client thread");
    }
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, (n_threads * jobs_per_thread) as u64);
    assert_eq!(stats.failed_jobs, 0);
    assert_eq!(stats.elements, (n_threads * jobs_per_thread * len) as u64);
}

/// A malformed job running *concurrently* with a healthy job fails alone:
/// the healthy job's values and per-job metrics are identical to the same
/// job run on a pristine service, and the bank keeps serving afterwards.
#[test]
fn failed_job_does_not_corrupt_concurrent_job() {
    let (a, b) = vectors(40, 99);

    // Reference: the healthy job alone on an identical pristine bank (the
    // simulator is deterministic, so per-job metrics must match exactly).
    let svc = mul_service(2, 4);
    let reference = svc.submit(&a, &b).expect("submit").wait().expect("wait");
    svc.shutdown();

    let svc = mul_service(2, 4);
    let healthy = svc.submit(&a, &b).expect("submit");
    // Malformed operand buried in the middle chunk: chunks before and after
    // it execute, the job still fails as a unit.
    let mut bad_a = vec![3u64; 12];
    bad_a[5] = 1 << 33;
    let bad_b = vec![7u64; 12];
    let bad = svc.submit(&bad_a, &bad_b).expect("submit");

    let err = bad.wait().expect_err("oversized operand must fail its job");
    assert!(format!("{err:#}").contains("exceeds"), "unexpected error: {err:#}");

    let res = healthy.wait().expect("healthy job must be unaffected");
    assert_eq!(res.scalars(), reference.scalars());
    assert_eq!(res.sim_cycles, reference.sim_cycles, "failed neighbor leaked cycles into the healthy job");
    assert_eq!(res.control_bits, reference.control_bits, "failed neighbor leaked control traffic");

    // The bank is still fully serviceable.
    let (a2, b2) = vectors(10, 123);
    let res2 = svc.submit(&a2, &b2).expect("submit").wait().expect("wait");
    for i in 0..10 {
        assert_eq!(res2.scalars()[i], a2[i] * b2[i]);
    }
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, 2);
    assert_eq!(stats.failed_jobs, 1);
}

/// Killing a worker mid-job is survivable: the chunk it had accepted but
/// not executed requeues to the surviving workers and the job completes
/// with correct results.
#[test]
fn killed_worker_chunks_requeue_to_survivors() {
    let svc = mul_service(3, 4);
    let (a, b) = vectors(60, 7); // 15 chunks across 3 workers
    let handle = svc.submit(&a, &b).expect("submit");
    svc.kill_worker(1).expect("kill");
    let res = handle.wait().expect("job must survive a killed worker");
    for i in 0..60 {
        assert_eq!(res.scalars()[i], a[i] * b[i], "element {i}");
    }
    // The two survivors keep serving.
    let (a2, b2) = vectors(24, 8);
    let res2 = svc.submit(&a2, &b2).expect("submit").wait().expect("wait");
    for i in 0..24 {
        assert_eq!(res2.scalars()[i], a2[i] * b2[i]);
    }
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, 2);
    assert_eq!(stats.failed_jobs, 0);
    assert_eq!(stats.elements, 84);
}

/// A worker panicking mid-chunk (simulated crossbar dying) is contained:
/// the worker retires, the rest of the bank keeps serving correctly.
#[test]
fn worker_panic_is_contained() {
    let svc = mul_service(4, 8);
    svc.inject_worker_panic().expect("inject");
    for j in 0..5u64 {
        let (a, b) = vectors(30, j + 50);
        let res = svc.submit(&a, &b).expect("submit").wait().expect("bank must keep serving after a crash");
        for i in 0..30 {
            assert_eq!(res.scalars()[i], a[i] * b[i]);
        }
    }
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, 5);
}

/// Regression: injecting a fault into an already-dead bank must not wedge
/// shutdown (the poison chunk used to sit in the queue forever, and the
/// dispatcher's drain condition never held).
#[test]
fn fault_injection_on_dead_bank_does_not_wedge_shutdown() {
    let svc = mul_service(1, 4);
    svc.kill_worker(0).expect("kill");
    svc.inject_worker_panic().expect("inject");
    let stats = svc.shutdown(); // must return, not deadlock
    assert_eq!(stats.jobs, 0);
}

/// Regression (the ghost-row bug): a job on a previously-used bank must
/// report exactly the metrics it reports on a pristine bank. Before the
/// fix, operands left over from a larger earlier batch kept switching
/// memristors, so per-job `switch_events` depended on bank history.
#[test]
fn reused_bank_reports_identical_per_job_metrics() {
    // One crossbar, so every job lands on the same (increasingly dirty) bank.
    let svc = mul_service(1, 8);
    // Pollute all 8 rows.
    let (big_a, big_b) = vectors(8, 1);
    svc.submit(&big_a, &big_b).expect("submit").wait().expect("wait");

    // The same 3-element job twice on the now-used bank.
    let (a, b) = vectors(3, 2);
    let r1 = svc.submit(&a, &b).expect("submit").wait().expect("wait");
    let r2 = svc.submit(&a, &b).expect("submit").wait().expect("wait");
    assert_eq!(r1.scalars(), r2.scalars());
    assert_eq!(r1.switch_events, r2.switch_events, "ghost rows leaked switching energy into the second run");
    assert_eq!(r1.sim_cycles, r2.sim_cycles);
    assert_eq!(r1.control_bits, r2.control_bits);
    assert!(r1.switch_events > 0);
    svc.shutdown();

    // And against a pristine bank: bit-identical per-job metrics.
    let svc = mul_service(1, 8);
    let r3 = svc.submit(&a, &b).expect("submit").wait().expect("wait");
    assert_eq!(r1.scalars(), r3.scalars());
    assert_eq!(r1.switch_events, r3.switch_events, "used bank must match a pristine bank exactly");
    assert_eq!(r1.sim_cycles, r3.sim_cycles);
    svc.shutdown();
}

/// Tentpole: single-element jobs submitted together share row-batches
/// instead of each paying a full program replay, and the occupancy
/// counters show it. The linger window is made long so the 8 jobs
/// deterministically pack into one full batch (dispatch on fullness, not
/// on the timer) regardless of scheduling noise.
#[test]
fn small_jobs_coalesce_into_shared_batches() {
    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars: 1,
        rows: 8,
        linger: Duration::from_secs(5),
        ..Default::default()
    })
    .expect("service");
    let mut pending = Vec::new();
    for j in 0..8u64 {
        let (a, b) = vectors(1, j + 10);
        let handle = svc.submit(&a, &b).expect("submit");
        pending.push((a, b, handle));
    }
    for (a, b, handle) in pending {
        let res = handle.wait().expect("wait");
        assert_eq!(res.scalars(), &[a[0] * b[0]]);
        assert!(res.switch_events > 0, "each job gets its own row-range energy");
    }
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, 8);
    assert_eq!(stats.elements, 8);
    assert_eq!(stats.chunks, 8, "each job is still its own segment");
    assert_eq!(stats.batches, 1, "eight 1-element jobs pack into one full batch");
    assert_eq!(stats.occupied_rows, 8);
    assert_eq!(stats.capacity_rows, 8);
    assert!((stats.mean_occupancy() - 1.0).abs() < 1e-12);
}

/// Ablation guardrail: with coalescing disabled every segment ships alone,
/// which is exactly what the coalescing bench measures against.
#[test]
fn coalescing_disabled_ships_each_chunk_alone() {
    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars: 1,
        rows: 8,
        coalescing: false,
        ..Default::default()
    })
    .expect("service");
    let mut pending = Vec::new();
    for j in 0..6u64 {
        let (a, b) = vectors(1, j + 30);
        let handle = svc.submit(&a, &b).expect("submit");
        pending.push((a, b, handle));
    }
    for (a, b, handle) in pending {
        let res = handle.wait().expect("wait");
        assert_eq!(res.scalars(), &[a[0] * b[0]]);
    }
    let stats = svc.shutdown();
    assert_eq!(stats.batches, 6, "disabled coalescing must not pack");
    assert_eq!(stats.occupied_rows, 6);
    assert_eq!(stats.capacity_rows, 48);
}

/// A malformed single-element job co-batched with healthy single-element
/// jobs fails alone: the co-tenants of its *shared batch* still complete
/// with correct values. Seven healthy jobs plus the bad one fill the batch
/// exactly, and the long linger window guarantees they genuinely share it.
#[test]
fn segment_failure_in_shared_batch_spares_co_tenants() {
    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model: ModelKind::Minimal,
        n_crossbars: 1,
        rows: 8,
        linger: Duration::from_secs(5),
        ..Default::default()
    })
    .expect("service");
    let mut healthy = Vec::new();
    for j in 0..7u64 {
        let (a, b) = vectors(1, j + 70);
        let handle = svc.submit(&a, &b).expect("submit");
        healthy.push((a, b, handle));
    }
    // Oversized operand as the eighth segment: the batch fills and ships.
    let bad = svc.submit(&[1u64 << 33], &[3]).expect("submit");
    let err = bad.wait().expect_err("oversized operand must fail its job");
    assert!(format!("{err:#}").contains("exceeds"), "unexpected error: {err:#}");
    for (a, b, handle) in healthy {
        let res = handle.wait().expect("co-batched jobs must survive a bad neighbor");
        assert_eq!(res.scalars(), &[a[0] * b[0]]);
    }
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, 7);
    assert_eq!(stats.failed_jobs, 1);
    assert_eq!(stats.batches, 1, "all eight segments shared one batch");
    assert_eq!(stats.elements, 7, "only healthy elements count");
}

/// When every worker is gone, pending jobs fail cleanly (no handle hangs)
/// and new submissions are rejected up front.
#[test]
fn dead_bank_fails_cleanly_instead_of_hanging() {
    let svc = mul_service(1, 4);
    // The poison chunk is queued (and thus executed) before the job's
    // chunks, so the bank's only worker dies with the job still pending.
    svc.inject_worker_panic().expect("inject");
    let (a, b) = vectors(20, 5);
    let pending = svc.submit(&a, &b).expect("submit");
    assert!(pending.wait().is_err(), "job on a dead bank must fail, not hang");

    let next = svc.submit(&a, &b).expect("submit");
    assert!(next.wait().is_err(), "submissions to a dead bank must fail cleanly");
    let stats = svc.shutdown();
    assert_eq!(stats.jobs, 0);
    assert_eq!(stats.failed_jobs, 2);
}
