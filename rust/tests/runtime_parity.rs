//! Experiment E14: the bit-packed rust simulator and the AOT-compiled
//! JAX/Pallas gate-step kernel (via PJRT) must agree bit-for-bit — on
//! random programs and on a full MultPIM multiplication — through the same
//! `PimBackend` trait the rest of the system uses.
//!
//! Requires `make artifacts` and a build with `--features xla` (the tests
//! skip with a loud message when either is absent, e.g. under a bare
//! `cargo test` before the python build step).

use partition_pim::algorithms::multpim::{build_multpim, MultPimVariant};
use partition_pim::backend::{ExecPipeline, PimBackend};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::operation::{GateOp, Operation};
use partition_pim::runtime::{artifact_path, XlaCrossbar};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifact_path(&dir, 16, 256, 8).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing at {dir:?} — run `make artifacts` first");
        None
    }
}

/// The XLA backend, or a loud skip when the artifact cannot be loaded
/// (missing `make artifacts` output, or a build without `--features xla`).
fn xla_backend(geom: Geometry, dir: &std::path::Path) -> Option<XlaCrossbar> {
    match XlaCrossbar::new(geom, dir) {
        Ok(x) => Some(x),
        Err(e) => {
            eprintln!("SKIP: XLA backend unavailable: {e}");
            None
        }
    }
}

fn geom() -> Geometry {
    Geometry::new(256, 8, 16).unwrap()
}

struct Rng(u64);
impl Rng {
    fn below(&mut self, n: usize) -> usize {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        (self.0 % n as u64) as usize
    }
}

#[test]
fn random_programs_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let g = geom();
    let Some(mut xla) = xla_backend(g, &dir) else { return };
    let mut rng = Rng(0x5eed);

    for trial in 0..5 {
        let mut sim = Crossbar::new(g, GateSet::NotNor);
        sim.state.fill_random(trial as u64 + 1);
        xla.load_state(&sim.state).expect("load");

        // Random valid program: parallel ops + serial ops + inits.
        let mut ops = Vec::new();
        for _ in 0..30 {
            match rng.below(3) {
                0 => {
                    // parallel in-place ops
                    let ia = rng.below(g.m());
                    let mut io = rng.below(g.m());
                    while io == ia {
                        io = rng.below(g.m());
                    }
                    ops.push(Operation::Gates((0..g.k).map(|p| GateOp::not(g.col(p, ia), g.col(p, io))).collect()));
                }
                1 => {
                    let a = rng.below(g.n);
                    let b = rng.below(g.n);
                    let mut o = rng.below(g.n);
                    while o == a || o == b {
                        o = rng.below(g.n);
                    }
                    ops.push(Operation::serial(GateOp::nor(a, b, o)));
                }
                _ => {
                    let cols: Vec<usize> = (0..1 + rng.below(20)).map(|_| rng.below(g.n)).collect();
                    ops.push(Operation::Init { cols, value: rng.below(2) == 0 });
                }
            }
        }

        sim.execute_ops(&ops).expect("sim");
        xla.execute_ops(&ops).expect("xla");
        assert_eq!(xla.state_bits().expect("state"), sim.state, "trial {trial}");
    }
}

#[test]
fn multpim_program_parity() {
    let Some(dir) = artifacts_dir() else { return };
    let g = geom();
    let mult = build_multpim(g, MultPimVariant::Fast).expect("build");

    let mut sim = Crossbar::new(g, GateSet::NotNor);
    let cases: Vec<(u64, u64)> = (0..16).map(|i| ((i * 37 + 11) % 256, (i * 91 + 5) % 256)).collect();
    for (r, &(a, b)) in cases.iter().enumerate() {
        mult.load(&mut sim.state, r, a, b).expect("load");
    }
    let Some(mut xla) = xla_backend(g, &dir) else { return };
    xla.load_state(&sim.state).expect("load");

    // The same program object runs both backends through the pipeline API.
    mult.program.execute(&mut ExecPipeline::direct(&mut sim)).expect("sim");
    mult.program.execute(&mut ExecPipeline::direct(&mut xla)).expect("xla");
    let xla_state = xla.state_bits().expect("state");
    assert_eq!(xla_state, sim.state);

    // And the products are right on both backends.
    for (r, &(a, b)) in cases.iter().enumerate() {
        assert_eq!(mult.read_product(&sim.state, r).expect("read"), a * b);
        assert_eq!(mult.read_product(&xla_state, r).expect("read"), a * b);
    }
}
