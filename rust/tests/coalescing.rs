//! Differential properties of cross-job chunk coalescing: a coalesced
//! mixed-job batch must be observationally identical — bitwise — to the
//! same jobs executed one-per-chunk on a pristine bank, including when a
//! co-batched segment fails.

use partition_pim::coordinator::worker::{workload_geometry, ChunkValues, Payload, Segment, Worker, WorkloadKind};
use partition_pim::isa::models::ModelKind;

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Self(seed.wrapping_mul(0x9e3779b97f4a7c15).max(1))
    }

    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

const ROWS: usize = 16;

fn worker() -> Worker {
    let geom = workload_geometry(WorkloadKind::Mul32, ModelKind::Minimal, ROWS).unwrap();
    Worker::new(WorkloadKind::Mul32, ModelKind::Minimal, geom).unwrap()
}

/// P12: for random mixes of small jobs, the coalesced batch on a *used*
/// bank produces bitwise-identical values to each job run alone as its own
/// chunk on a pristine bank.
#[test]
fn p12_coalesced_values_match_per_chunk_execution() {
    // The coalesced worker is deliberately pre-dirtied: correctness must
    // not depend on bank history.
    let mut coalesced = worker();
    let dirty: Vec<(u64, u64)> = (0..ROWS as u64).map(|i| (0xffff_0000 + i, 0xeeee_0000 + i)).collect();
    coalesced.run_batch(&dirty).unwrap();
    // One reference worker reused across segments: run_batch clears rows,
    // so reuse is itself part of the property under test.
    let mut reference = worker();

    for trial in 0..12u64 {
        let mut rng = Rng::new(trial + 1);
        // Random segment sizes filling at most the batch.
        let mut segments = Vec::new();
        let mut fill = 0usize;
        let mut job = 0u64;
        while fill < ROWS {
            let span = (1 + rng.below((ROWS - fill).min(5) as u64)) as usize;
            let pairs: Vec<(u64, u64)> = (0..span).map(|_| (rng.next() & 0xffff_ffff, rng.next() & 0xffff_ffff)).collect();
            segments.push(Segment { job, offset: 0, payload: Payload::Pairs(pairs), remaps: 0 });
            job += 1;
            fill += span;
            if rng.below(4) == 0 {
                break; // sometimes leave the batch underfull
            }
        }

        let (reports, delta) = coalesced.run_segments(&segments).unwrap();
        assert_eq!(reports.len(), segments.len());
        let mut attributed_switches = 0u64;
        let mut attributed_cycles = 0u64;
        for (seg, rep) in segments.iter().zip(&reports) {
            let Payload::Pairs(pairs) = &seg.payload else { unreachable!() };
            let (expect, _) = reference.run_batch(pairs).unwrap();
            let got = rep.values.as_ref().unwrap_or_else(|e| panic!("trial {trial} job {} failed: {e}", seg.job));
            let ChunkValues::Scalars(got) = got else { panic!("scalar workload") };
            assert_eq!(got, &expect, "trial {trial} job {}", seg.job);
            attributed_switches += rep.switch_events;
            attributed_cycles += rep.sim_cycles;
        }
        // Attribution sanity: segment shares never exceed the batch totals.
        assert!(attributed_switches <= delta.switch_events, "trial {trial}");
        assert!(attributed_cycles <= delta.cycles, "trial {trial}");
    }
}

/// P13: a malformed operand in one co-batched segment fails only that
/// segment; its neighbors' values are still bitwise identical to pristine
/// per-chunk execution.
#[test]
fn p13_segment_failure_is_isolated_and_neighbors_exact() {
    let mut coalesced = worker();
    let mut reference = worker();
    for trial in 0..8u64 {
        let mut rng = Rng::new(0x5eed + trial);
        let good_a: Vec<(u64, u64)> = (0..3).map(|_| (rng.next() & 0xffff_ffff, rng.next() & 0xffff_ffff)).collect();
        let good_b: Vec<(u64, u64)> = (0..4).map(|_| (rng.next() & 0xffff_ffff, rng.next() & 0xffff_ffff)).collect();
        // Job 1's second element exceeds the 32-bit operand range.
        let mut bad = good_a.clone();
        bad[1].0 = 1 << 33;
        let segments = vec![
            Segment { job: 0, offset: 0, payload: Payload::Pairs(good_a.clone()), remaps: 0 },
            Segment { job: 1, offset: 0, payload: Payload::Pairs(bad), remaps: 0 },
            Segment { job: 2, offset: 0, payload: Payload::Pairs(good_b.clone()), remaps: 0 },
        ];
        let (reports, _) = coalesced.run_segments(&segments).unwrap();
        let err = reports[1].values.as_ref().expect_err("oversized operand must fail its segment");
        assert!(err.contains("exceeds"), "trial {trial}: unexpected error {err}");

        for (seg_pairs, rep) in [(&good_a, &reports[0]), (&good_b, &reports[2])] {
            let (expect, _) = reference.run_batch(seg_pairs).unwrap();
            let got = rep.values.as_ref().expect("healthy co-batched segment must complete");
            let ChunkValues::Scalars(got) = got else { panic!("scalar workload") };
            assert_eq!(got, &expect, "trial {trial}: bad neighbor corrupted a healthy segment");
        }
    }
}
