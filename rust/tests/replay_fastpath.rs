//! The replay fast path must be invisible: replaying through the
//! decode-once trusted op cache (with or without word-range parallelism)
//! has to be bitwise- and metric-identical to re-streaming the full wire —
//! at the program level (the fig6 multiply workloads), the worker batch
//! loop, and the serving stack (DESIGN.md §Replay fast path, experiment
//! E17).

use partition_pim::backend::{ExecPipeline, ReplayMode};
use partition_pim::coordinator::worker::{compile_workload, workload_geometry, Worker, WorkloadKind};
use partition_pim::coordinator::{PimService, ServiceConfig};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::isa::models::ModelKind;

/// E17 / fig6 parity: the full 32-bit multiply program of every partitioned
/// model replays identically under Wire and Decoded modes — final state,
/// cycles, gate events, switching energy, control bits and messages —
/// including across 2 and 4 parallel word ranges.
#[test]
fn fig6_mul32_replay_parity_per_model() {
    for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let geom = workload_geometry(WorkloadKind::Mul32, model, 130).unwrap(); // 3 words/col
        let (prog, _) = compile_workload(WorkloadKind::Mul32, model, geom).unwrap();
        let prepared = {
            let mut scratch = Crossbar::new(geom, GateSet::NotNor);
            prog.prepare(&mut ExecPipeline::wire(model, &mut scratch)).unwrap()
        };
        assert!(prepared.is_decoded());
        let mut outcomes = Vec::new();
        for (mode, threads) in [(ReplayMode::Wire, 1), (ReplayMode::Decoded, 1), (ReplayMode::Decoded, 2), (ReplayMode::Decoded, 4)] {
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            xb.state.fill_random(23);
            let mut pipe = ExecPipeline::wire(model, &mut xb);
            pipe.set_replay_mode(mode);
            pipe.set_replay_threads(threads);
            pipe.run_prepared(&prepared).unwrap();
            let stats = pipe.stats();
            let m = pipe.metrics();
            drop(pipe);
            outcomes.push((xb.state, m.cycles, m.gate_events, m.switch_events, stats.control_bits, stats.messages));
        }
        for o in &outcomes[1..] {
            assert_eq!(o, &outcomes[0], "{}: cached replay diverged from the wire path", model.name());
        }
    }
}

/// Worker-level parity: Decoded and Wire replay workers serve identical
/// batch values and identical per-batch metric deltas (including the exact
/// per-row switch attribution folded into the segment reports), and the
/// word-range-parallel worker matches both.
#[test]
fn worker_replay_modes_serve_identical_batches() {
    for model in [ModelKind::Minimal, ModelKind::Standard] {
        let geom = workload_geometry(WorkloadKind::Mul32, model, 130).unwrap();
        let mut decoded = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        let mut wire = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        wire.set_replay(ReplayMode::Wire, 1);
        let mut threaded = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        threaded.set_replay(ReplayMode::Decoded, 4);
        let pairs: Vec<(u64, u64)> = (0..8).map(|i| (0x1234_5678 ^ (i * 991), 0x9abc + i * 77)).collect();
        let (v_dec, m_dec) = decoded.run_batch(&pairs).unwrap();
        let (v_wire, m_wire) = wire.run_batch(&pairs).unwrap();
        let (v_thr, m_thr) = threaded.run_batch(&pairs).unwrap();
        for (i, &(a, b)) in pairs.iter().enumerate() {
            assert_eq!(v_dec[i], a * b, "{}", model.name());
        }
        assert_eq!(v_dec, v_wire);
        assert_eq!(m_dec, m_wire, "{}: decoded batch metrics must match the wire path", model.name());
        assert_eq!(v_dec, v_thr);
        assert_eq!(m_dec, m_thr, "{}: word-range-parallel metrics must match", model.name());
    }
}

/// SHA-3 parity: the typed-message (gate-class field) wire stream of the
/// HashPIM Keccak-f[1600] program replays identically through the decoded
/// cache — values bitwise-equal to the software oracle in every mode, and
/// per-batch metric deltas identical between Wire, Decoded and word-range-
/// parallel Decoded replay.
#[test]
fn sha3_decoded_replay_matches_wire() {
    use partition_pim::algorithms::sha3;
    let model = ModelKind::Minimal;
    let geom = workload_geometry(WorkloadKind::Sha3, model, 4).unwrap();
    let mut decoded = Worker::new(WorkloadKind::Sha3, model, geom).unwrap();
    let mut wire = Worker::new(WorkloadKind::Sha3, model, geom).unwrap();
    wire.set_replay(ReplayMode::Wire, 1);
    let mut threaded = Worker::new(WorkloadKind::Sha3, model, geom).unwrap();
    threaded.set_replay(ReplayMode::Decoded, 2);
    let states: Vec<[u64; 25]> = (0..4)
        .map(|r| {
            let mut st = [0u64; 25];
            for (i, lane) in st.iter_mut().enumerate() {
                *lane = (0xa076_1d64_78bd_642fu64).wrapping_mul(r as u64 + 1).rotate_left((i * 7) as u32);
            }
            st
        })
        .collect();
    let (v_dec, m_dec) = decoded.run_sha3_batch(&states).unwrap();
    let (v_wire, m_wire) = wire.run_sha3_batch(&states).unwrap();
    let (v_thr, m_thr) = threaded.run_sha3_batch(&states).unwrap();
    for (r, st) in states.iter().enumerate() {
        let mut want = *st;
        sha3::keccak_f_sw(&mut want);
        assert_eq!(v_dec[r], want, "decoded replay diverged from the software oracle on row {r}");
    }
    assert_eq!(v_dec, v_wire);
    assert_eq!(m_dec, m_wire, "sha3 decoded batch metrics must match the wire path");
    assert_eq!(v_dec, v_thr);
    assert_eq!(m_dec, m_thr, "sha3 word-range-parallel metrics must match");
}

/// Service-level parity: the same job stream returns identical values and
/// identical per-job metric attribution whether the bank replays through
/// the decoded cache (serial or word-parallel) or the full wire re-decode.
#[test]
fn service_replay_modes_agree() {
    let run = |mode: ReplayMode, threads: usize| {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 2,
            rows: 8,
            replay_mode: mode,
            replay_threads: threads,
            ..Default::default()
        })
        .unwrap();
        let a: Vec<u64> = (0..24).map(|i| (i * 2654435761) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..24).map(|i| (i * 40503 + 12345) & 0xffff_ffff).collect();
        let res = svc.submit(&a, &b).unwrap().wait().unwrap();
        svc.shutdown();
        (res.values.scalars().to_vec(), res.sim_cycles, res.control_bits, res.switch_events)
    };
    let dec = run(ReplayMode::Decoded, 1);
    let wire = run(ReplayMode::Wire, 1);
    assert_eq!(dec, wire, "decoded and wire banks must attribute identically");
    for (i, &v) in dec.0.iter().enumerate() {
        let (a, b) = ((i as u64 * 2654435761) & 0xffff_ffff, (i as u64 * 40503 + 12345) & 0xffff_ffff);
        assert_eq!(v, a * b, "element {i}");
    }
}
