//! One fire + one quiet case per verifier rule (V001–V043), plus the
//! acceptance case: a deliberately hazardous program — one the encoder
//! accepts but the periphery silently mis-executes — is rejected by the
//! pipeline's verify stage before it reaches any backend.

use partition_pim::backend::ExecPipeline;
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::{GateSet, GateType};
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::isa::encode;
use partition_pim::isa::models::ModelKind;
use partition_pim::isa::operation::{GateOp, Operation};
use partition_pim::periphery;
use partition_pim::verify::{verify_ops, Report, Rule, Severity, VerifyOptions};

fn geom() -> Geometry {
    Geometry::new(256, 8, 8).unwrap() // k = 8, m = 32
}

fn opts(model: ModelKind) -> VerifyOptions {
    VerifyOptions::new(model, GateSet::NotNor)
}

fn check(ops: &[Operation], model: ModelKind) -> Report {
    verify_ops("test", ops, &geom(), &opts(model))
}

fn check_with(ops: &[Operation], o: &VerifyOptions) -> Report {
    verify_ops("test", ops, &geom(), o)
}

/// A parallel-style cycle that is legal under every partitioned model.
fn clean_op(g: &Geometry) -> Operation {
    Operation::Gates((0..g.k).map(|p| GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 3))).collect())
}

/// Aperiodic input partitions {0, 1, 4} at distance 0: physically valid,
/// *accepted by the minimal encoder* (the range-generator fields only
/// capture the first gap), but expanded by the decoder to partitions 0..=4
/// — five gates instead of three.
fn aperiodic_op(g: &Geometry) -> Operation {
    Operation::Gates(vec![
        GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
        GateOp::nor(g.col(1, 0), g.col(1, 1), g.col(1, 3)),
        GateOp::nor(g.col(4, 0), g.col(4, 1), g.col(4, 3)),
    ])
}

#[test]
fn rule_codes_are_unique() {
    let mut codes = std::collections::HashSet::new();
    let mut names = std::collections::HashSet::new();
    for r in Rule::ALL {
        assert!(codes.insert(r.code()), "duplicate code {}", r.code());
        assert!(names.insert(r.name()), "duplicate name {}", r.name());
    }
}

#[test]
fn v001_empty_cycle() {
    let g = geom();
    let fire = check(&[Operation::Gates(vec![]), Operation::Init { cols: vec![], value: true }], ModelKind::Unlimited);
    assert_eq!(fire.diagnostics.iter().filter(|d| d.rule == Rule::EmptyCycle).count(), 2);
    assert!(!fire.is_clean());
    let quiet = check(&[Operation::init1(vec![0]), clean_op(&g)], ModelKind::Unlimited);
    assert!(!quiet.has(Rule::EmptyCycle));
}

#[test]
fn v002_column_range() {
    let g = geom();
    let fire = check(
        &[Operation::init1(vec![g.n + 1]), Operation::serial(GateOp::nor(0, 1, g.n)), Operation::serial(GateOp::nor(g.n + 5, 1, 9))],
        ModelKind::Unlimited,
    );
    assert_eq!(fire.diagnostics.iter().filter(|d| d.rule == Rule::ColumnRange).count(), 3);
    let quiet = check(&[clean_op(&g)], ModelKind::Unlimited);
    assert!(!quiet.has(Rule::ColumnRange));
}

#[test]
fn v003_output_aliases_input() {
    let fire = check(&[Operation::serial(GateOp::nor(5, 6, 5))], ModelKind::Unlimited);
    assert!(fire.has(Rule::OutputAliasesInput) && !fire.is_clean());
    let quiet = check(&[Operation::serial(GateOp::nor(5, 6, 7))], ModelKind::Unlimited);
    assert!(!quiet.has(Rule::OutputAliasesInput));
}

#[test]
fn v004_gate_set_violation() {
    let g = geom();
    // A FELIX Min3 under a NOT/NOR-only crossbar, an init pseudo-gate in a
    // gate cycle, and an arity mismatch.
    let fire = check(
        &[
            Operation::serial(GateOp { gate: GateType::Min3, ins: vec![0, 1, 2], out: 3 }),
            Operation::Gates(vec![GateOp { gate: GateType::Init1, ins: vec![], out: 3 }]),
            Operation::serial(GateOp { gate: GateType::Nor, ins: vec![0], out: 3 }),
        ],
        ModelKind::Unlimited,
    );
    assert_eq!(fire.diagnostics.iter().filter(|d| d.rule == Rule::GateSetViolation).count(), 3);
    let quiet = check(&[clean_op(&g)], ModelKind::Unlimited);
    assert!(!quiet.has(Rule::GateSetViolation));
}

#[test]
fn v005_section_overlap() {
    let g = geom();
    let fire = check(
        &[Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(2, 3)), // span [0,2]
            GateOp::nor(g.col(1, 0), g.col(1, 1), g.col(1, 3)), // span [1,1]
        ])],
        ModelKind::Unlimited,
    );
    assert!(fire.has(Rule::SectionOverlap) && !fire.is_clean());
    let quiet = check(&[clean_op(&g)], ModelKind::Unlimited);
    assert!(!quiet.has(Rule::SectionOverlap));
}

#[test]
fn v010_write_write_hazard() {
    let g = geom();
    let shared = g.col(4, 3);
    let fire = check(
        &[Operation::Gates(vec![GateOp::nor(g.col(0, 0), g.col(0, 1), shared), GateOp::nor(g.col(6, 0), g.col(6, 1), shared)])],
        ModelKind::Unlimited,
    );
    assert!(fire.has(Rule::WriteWriteHazard) && !fire.is_clean());
    let quiet = check(&[clean_op(&g)], ModelKind::Unlimited);
    assert!(!quiet.has(Rule::WriteWriteHazard));
}

#[test]
fn v011_read_write_hazard() {
    let g = geom();
    let mid = g.col(2, 3);
    let fire = check(
        &[Operation::Gates(vec![GateOp::nor(g.col(0, 0), g.col(0, 1), mid), GateOp::nor(mid, g.col(4, 1), g.col(4, 5))])],
        ModelKind::Unlimited,
    );
    assert!(fire.has(Rule::ReadWriteHazard) && !fire.is_clean());
    let quiet = check(&[clean_op(&g)], ModelKind::Unlimited);
    assert!(!quiet.has(Rule::ReadWriteHazard));
}

/// The resolved `operation.rs` "physically fine" policy: mixed directions
/// are a V012 *warning* under the unlimited model (representable on its
/// wire, flagged for portability) and a V012 *error* under standard /
/// minimal (their shared-direction formats cannot express the cycle).
#[test]
fn v012_mixed_direction_policy() {
    let g = geom();
    let mixed = Operation::Gates(vec![
        GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(1, 3)), // rightward
        GateOp::nor(g.col(5, 0), g.col(5, 1), g.col(4, 3)), // leftward
    ]);
    let under_unlimited = check(std::slice::from_ref(&mixed), ModelKind::Unlimited);
    let diag = under_unlimited.diagnostics.iter().find(|d| d.rule == Rule::MixedDirection).expect("V012 must fire");
    assert_eq!(diag.severity, Severity::Warning);
    assert!(under_unlimited.is_clean(), "a warning must not make the report unclean");
    let under_standard = check(std::slice::from_ref(&mixed), ModelKind::Standard);
    let diag = under_standard.diagnostics.iter().find(|d| d.rule == Rule::MixedDirection).expect("V012 must fire");
    assert_eq!(diag.severity, Severity::Error);
    assert!(!under_standard.is_clean());
    // Uniform-direction cycles stay quiet everywhere.
    let uniform = Operation::Gates(vec![
        GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(1, 3)),
        GateOp::nor(g.col(4, 0), g.col(4, 1), g.col(5, 3)),
    ]);
    assert!(!check(std::slice::from_ref(&uniform), ModelKind::Standard).has(Rule::MixedDirection));
}

#[test]
fn v020_baseline_multi_gate() {
    let g = geom();
    let two = Operation::Gates(vec![GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)), GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(2, 3))]);
    let fire = check(std::slice::from_ref(&two), ModelKind::Baseline);
    assert!(fire.has(Rule::BaselineMultiGate) && !fire.is_clean());
    assert!(!check(&[Operation::serial(GateOp::nor(0, 1, 9))], ModelKind::Baseline).has(Rule::BaselineMultiGate));
    assert!(!check(std::slice::from_ref(&two), ModelKind::Unlimited).has(Rule::BaselineMultiGate));
}

#[test]
fn v021_split_input() {
    let g = geom();
    let split = Operation::serial(GateOp::nor(g.col(0, 0), g.col(1, 1), g.col(2, 3)));
    let fire = check(std::slice::from_ref(&split), ModelKind::Standard);
    assert!(fire.has(Rule::SplitInput) && !fire.is_clean());
    assert!(!check(std::slice::from_ref(&split), ModelKind::Unlimited).has(Rule::SplitInput));
}

#[test]
fn v022_identical_indices() {
    let g = geom();
    let differing = Operation::Gates(vec![
        GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)), // indices (0, 1, 3)
        GateOp::nor(g.col(2, 0), g.col(2, 2), g.col(2, 3)), // indices (0, 2, 3)
    ]);
    let fire = check(std::slice::from_ref(&differing), ModelKind::Standard);
    assert!(fire.has(Rule::IdenticalIndices) && !fire.is_clean());
    assert!(!check(std::slice::from_ref(&differing), ModelKind::Unlimited).has(Rule::IdenticalIndices));
    assert!(!check(&[clean_op(&g)], ModelKind::Standard).has(Rule::IdenticalIndices));
}

#[test]
fn v023_uniform_distance() {
    let g = geom();
    // Figure 2(d): distances (0, 1, 0) — standard-legal, minimal-illegal.
    let fig2d = Operation::Gates(vec![
        GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
        GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(3, 3)),
        GateOp::nor(g.col(5, 0), g.col(5, 1), g.col(5, 3)),
    ]);
    let fire = check(std::slice::from_ref(&fig2d), ModelKind::Minimal);
    assert!(fire.has(Rule::UniformDistance) && !fire.is_clean());
    assert!(!check(std::slice::from_ref(&fig2d), ModelKind::Standard).has(Rule::UniformDistance));
    assert!(!check(&[clean_op(&g)], ModelKind::Minimal).has(Rule::UniformDistance));
}

#[test]
fn v024_periodic() {
    let g = geom();
    let fire = check(&[aperiodic_op(&g)], ModelKind::Minimal);
    assert!(fire.has(Rule::Periodic) && !fire.is_clean());
    // Periodic T=2 > d=0: quiet and fully clean under minimal.
    let periodic = Operation::Gates(
        [0usize, 2, 4].iter().map(|&p| GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 3))).collect(),
    );
    let quiet = check(std::slice::from_ref(&periodic), ModelKind::Minimal);
    assert!(!quiet.has(Rule::Periodic));
    assert!(quiet.is_clean());
}

#[test]
fn v030_not_encodable() {
    let g = geom();
    // FELIX Min3 is a valid gate on a FELIX crossbar, but none of the
    // paper's two-input message formats can carry it.
    let o = VerifyOptions::new(ModelKind::Unlimited, GateSet::Felix);
    let min3 = Operation::serial(GateOp { gate: GateType::Min3, ins: vec![0, 1, 2], out: 3 });
    let fire = check_with(std::slice::from_ref(&min3), &o);
    assert!(fire.has(Rule::NotEncodable) && !fire.is_clean());
    let quiet = check_with(&[clean_op(&g)], &o);
    assert!(!quiet.has(Rule::NotEncodable));
}

#[test]
fn v031_decode_divergence() {
    let g = geom();
    let op = aperiodic_op(&g);
    // The encoder accepts the cycle; the decoder expands it differently.
    let msg = encode::to_message(ModelKind::Minimal, &op, &g).unwrap();
    let rec = periphery::reconstruct(&msg, &g).unwrap();
    assert_ne!(rec.normalized(), op.normalized());
    let fire = check(std::slice::from_ref(&op), ModelKind::Minimal);
    assert!(fire.has(Rule::DecodeDivergence) && !fire.is_clean());
    // The same placement is exactly representable under unlimited.
    assert!(!check(std::slice::from_ref(&op), ModelKind::Unlimited).has(Rule::DecodeDivergence));
}

#[test]
fn v040_uninit_read() {
    let ops = vec![Operation::init1(vec![2]), Operation::serial(GateOp::nor(0, 1, 2))];
    // With a declared input set, reading outside it is an error.
    let fire = check_with(&ops, &opts(ModelKind::Unlimited).with_inputs(vec![0]));
    let diag = fire.diagnostics.iter().find(|d| d.rule == Rule::UninitRead).expect("V040 must fire for column 1");
    assert_eq!(diag.severity, Severity::Error);
    assert!(!fire.is_clean());
    // Declaring both operands silences it.
    let quiet = check_with(&ops, &opts(ModelKind::Unlimited).with_inputs(vec![0, 1]));
    assert!(!quiet.has(Rule::UninitRead));
    // Without a declared input set it is only a note.
    let note = check(&ops, ModelKind::Unlimited);
    assert!(note.has(Rule::UninitRead));
    assert!(note.is_clean());
}

#[test]
fn v041_missing_init() {
    let fire = check(&[Operation::serial(GateOp::nor(0, 1, 2))], ModelKind::Unlimited);
    let diag = fire.diagnostics.iter().find(|d| d.rule == Rule::MissingInit).expect("V041 must fire");
    assert_eq!(diag.severity, Severity::Warning);
    assert!(fire.is_clean(), "a MAGIC-precondition warning does not reject the program");
    let quiet = check(&[Operation::init1(vec![2]), Operation::serial(GateOp::nor(0, 1, 2))], ModelKind::Unlimited);
    assert!(!quiet.has(Rule::MissingInit));
}

#[test]
fn v042_dead_write() {
    let fire = check(
        &[Operation::init1(vec![2]), Operation::serial(GateOp::nor(0, 1, 2)), Operation::init1(vec![2])],
        ModelKind::Unlimited,
    );
    assert!(fire.has(Rule::DeadWrite));
    assert!(fire.is_clean());
    // Reading the value before the re-initialization silences it.
    let quiet = check(
        &[
            Operation::init1(vec![2, 5]),
            Operation::serial(GateOp::nor(0, 1, 2)),
            Operation::serial(GateOp::nor(2, 4, 5)),
            Operation::init1(vec![2]),
        ],
        ModelKind::Unlimited,
    );
    assert!(!quiet.has(Rule::DeadWrite));
}

#[test]
fn v043_scratch_leak() {
    let g = geom();
    let scratch = opts(ModelKind::Unlimited).with_scratch((30, 31));
    let touching = vec![Operation::init1(vec![g.col(0, 30)]), Operation::serial(GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 30)))];
    let fire = check_with(&touching, &scratch);
    assert!(fire.has(Rule::ScratchLeak) && !fire.is_clean());
    let quiet_ops = check_with(&[clean_op(&g)], &scratch);
    assert!(!quiet_ops.has(Rule::ScratchLeak));
    // Without a reserved scratch configuration the rule never fires.
    let unconfigured = check(&touching, ModelKind::Unlimited);
    assert!(!unconfigured.has(Rule::ScratchLeak));
}

/// Acceptance criterion: the deliberately hazardous program is rejected by
/// the pipeline's verify stage before reaching any backend — the encoder
/// alone would have accepted it and silently executed different gates.
#[test]
fn hazardous_program_rejected_before_any_backend() {
    let g = geom();
    let op = aperiodic_op(&g);
    op.validate(&g, GateSet::NotNor).unwrap();
    assert!(encode::encode(ModelKind::Minimal, &op, &g).is_ok(), "the encoder alone does not catch this");

    let mut xb = Crossbar::new(g, GateSet::NotNor);
    xb.state.fill_random(42);
    let before = xb.state.clone();
    let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
    let err = pipe.run_op(&op).unwrap_err();
    assert!(err.to_string().contains("V024") || err.to_string().contains("V031"), "rejection must cite the rule: {err}");
    assert_eq!(pipe.metrics().cycles, 0);
    assert_eq!(pipe.stats().messages, 0);
    drop(pipe);
    assert_eq!(xb.state, before);
}

/// Every built-in workload program the coordinator serves verifies clean
/// under its model — the in-test twin of the `repro lint` CI gate.
#[test]
fn builtin_workload_programs_verify_clean() {
    use partition_pim::coordinator::{compile_workload, workload_geometry, WorkloadKind};
    for kind in WorkloadKind::ALL {
        for model in ModelKind::ALL {
            let geom = workload_geometry(kind, model, 4).unwrap();
            let (program, _) = compile_workload(kind, model, geom).unwrap();
            let report = partition_pim::verify::verify_program(&program, model);
            assert!(report.is_clean(), "{kind:?} under {}:\n{}", model.name(), report.render());
        }
    }
}
