//! # PartitionPIM — practical memristive partitions for fast processing-in-memory
//!
//! Full-system reproduction of *PartitionPIM: Practical Memristive Partitions
//! for Fast Processing-in-Memory* (Leitersdorf, Ronen, Kvatinsky — cs.AR 2022).
//!
//! The paper designs the **practical periphery and control** for memristive
//! crossbar *partitions*: isolation transistors that let several stateful
//! logic gates (MAGIC NOR / NOT, FELIX) execute concurrently **within each
//! row**, on top of the inherent row-parallelism of stateful logic.
//!
//! Because the paper's substrate is memristive hardware, this crate builds the
//! entire stack as a cycle-accurate architectural simulation:
//!
//! * [`backend`] — the execution seam: the [`backend::PimBackend`] trait
//!   every physical realization implements (bit-packed, scalar reference,
//!   XLA/PJRT), and the composable [`backend::ExecPipeline`]
//!   (legalize → verify → encode → periphery-decode → backend) that every
//!   program executes through, with uniform metering of cycles, gates and
//!   control traffic at the stage boundaries. `prepare` applies the
//!   controller-side stages once and decodes the wire stream into a
//!   trusted op cache; replays then skip the per-run periphery decode
//!   (while still charging its control cost) and may execute in parallel
//!   word-range chunks — the replay fast path (DESIGN.md §Replay fast
//!   path), with [`backend::ReplayMode`] as the wire-path escape hatch.
//! * [`crossbar`] — the bit-packed, cycle-accurate crossbar simulator with
//!   stateful-logic gate semantics, partition transistors and section
//!   isolation, plus latency / energy (gate-count & switching) metrics.
//! * [`isa`] — the partition operation model (serial / parallel /
//!   semi-parallel), the three designs of the paper (**unlimited**,
//!   **standard**, **minimal**) as validators, bit-exact control-message
//!   codecs for each (30 / 607 / 79 / 36 bits at n=1024, k=32), and the
//!   legalizer that rewrites unsupported operations into supported
//!   alternatives (Section 5 of the paper).
//! * [`periphery`] — structural + functional models of the decoders: the
//!   *half-gates* technique (Table 1 opcodes), the standard model's opcode
//!   generator, the minimal model's range generator, and CMOS gate-count
//!   area models (including the naive Ω(k²) decoder stack for comparison).
//! * [`algorithms`] — PIM algorithms as micro-op programs: NOR full adders,
//!   N-bit addition, the optimized serial multiplier baseline, a
//!   MultPIM-style partitioned multiplier, partitioned bitonic sorting, and
//!   the HashPIM-style SHA-3 Keccak-f[1600] permutation (typed XOR/NOR/
//!   NOT/OR gate set, bit-sliced across partitions, verified against the
//!   published per-step cycle/gate table). Programs execute via
//!   `Program::execute(&mut ExecPipeline)` — one API for every backend and
//!   control path.
//! * [`verify`] — the whole-program static analyzer: per-cycle
//!   classification (serial / parallel / semi-parallel / init), a stable
//!   rule catalog (structural V00x, hazard V01x, model-conformance V02x,
//!   wire-representability V03x, dataflow V04x) and typed diagnostic
//!   reports. Wired in three layers: the pipeline's default
//!   `Stage::Verify` (rejects hazardous cycles before the wire), the
//!   `repro lint` CLI subcommand (checks every built-in program against
//!   every model), and the coordinator's compile cache (verifies each
//!   compiled workload once). See DESIGN.md §Verifier for the catalog.
//! * [`analysis`] — the combinatorial lower bounds on message length
//!   (443 / 46 / 25 bits) via a small big-integer implementation.
//! * [`coordinator`] — the L3 runtime: a concurrent, fault-isolated job
//!   scheduler with cross-job chunk coalescing. `submit` returns a
//!   `JobHandle` (any number of jobs in flight; completions routed by job
//!   id); a coalescer packs partial chunks from different jobs into shared
//!   full-occupancy row-batches, and workers stream pre-encoded control
//!   messages through the periphery decode stage of an `ExecPipeline`. A
//!   malformed operand fails only its own job (co-batched segments still
//!   complete), and a crashed worker's unexecuted batch requeues to the
//!   surviving workers (DESIGN.md §Coordinator). Latency, energy, and
//!   control traffic are metered per job — switching energy exactly, per
//!   row range — and per bank, with batch-occupancy counters. Above the
//!   banks, `coordinator::fleet::PimFleet` serves *mixed* traffic: it owns
//!   N banks with different workloads behind one cloneable `FleetClient`,
//!   routes each job to the least-loaded compatible bank, bounds queues
//!   with a typed `Overloaded` backpressure error, and absorbs bank death
//!   by rerouting jobs onto peers or warm-promoted hot spares, folding
//!   every bank's statistics into one `FleetStats` (DESIGN.md §Fleet).
//!   Every submission front door is the unified
//!   `submit_job(WorkloadKind, Payload)` — `submit` / `submit_sort` are
//!   one-line wrappers — and serving is wear-aware: a persistent per-row
//!   `WearMap` (switch events survive `clear_rows`; wear is physical)
//!   drives cold-row-first placement, stuck-at faults quarantine only the
//!   afflicted rows while segments remap onto healthy ones within a
//!   bounded retry budget (typed `RowQuarantined` on exhaustion), and
//!   `ServiceStats`/`FleetStats` report the endurance horizon — max
//!   per-row wear, wear Gini, projected time-to-first-failure under a
//!   configurable endurance budget (DESIGN.md §Wear).
//! * [`runtime`] — PJRT/XLA execution of the AOT-compiled JAX/Pallas
//!   crossbar-step artifact (`artifacts/*.hlo.txt`) as an independent
//!   `PimBackend`, used to cross-check the rust simulator (python never
//!   runs at request time). Gated behind the `xla` cargo feature.
//!
//! See `DESIGN.md` for the module map, the backend/pipeline architecture,
//! the experiment index, and the offline-environment substitutions.

pub mod algorithms;
pub mod analysis;
pub mod backend;
pub mod bench_support;
pub mod coordinator;
pub mod crossbar;
pub mod figures;
pub mod isa;
pub mod periphery;
pub mod runtime;
pub mod verify;

pub use backend::{ExecPipeline, PimBackend, PipelineStats, PreparedProgram, ReplayMode, ScalarCrossbar, Stage};
pub use crossbar::{
    crossbar::{Crossbar, Metrics},
    gate::{GateSet, GateType},
    geometry::Geometry,
    state::BitMatrix,
};
pub use isa::{
    models::ModelKind,
    operation::{GateOp, Operation},
};
