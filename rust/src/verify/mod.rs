//! Whole-program static analysis for partitioned PIM programs.
//!
//! The verifier takes a (raw or legalized) operation stream plus a
//! [`Geometry`] and a control [`ModelKind`] and produces a typed [`Report`]:
//! a per-cycle classification profile (serial / parallel / semi-parallel /
//! init, Section 2.1 of the paper) and a diagnostic list drawn from a stable
//! rule catalog (see [`Rule`] and `DESIGN.md` §Verifier):
//!
//! * **V00x structural** — empty cycles, column ranges, output/input
//!   aliasing, gate-set membership, overlapping sections.
//! * **V01x hazards** — intra-cycle write-write / read-write column overlap
//!   and the mixed-direction policy (warning under unlimited, error under
//!   standard / minimal).
//! * **V02x conformance** — the reduced operation-set criteria of each
//!   control model (No Split-Input, Identical Indices, Uniform Direction,
//!   Uniform Partition-Distance, Periodic), reported with per-gate spans
//!   *before* any encoder runs.
//! * **V03x representability** — an encode → periphery-decode dry run per
//!   cycle; V031 catches messages that encode fine but decode to *different*
//!   gates (silent mis-execution on the wire path).
//! * **V04x dataflow** — uninitialized reads, MAGIC init preconditions,
//!   dead writes, legalizer scratch-column leaks.
//!
//! Three entry points, one per integration layer:
//!
//! * [`verify_program`] / [`verify_ops`] — whole-program analysis, used by
//!   the `repro lint` CLI subcommand and the coordinator's compile cache.
//! * [`check_cycle`] — the single-cycle subset (V00x–V03x) behind the
//!   pipeline's [`crate::backend::Stage::Verify`] stage: error-severity
//!   diagnostics reject the operation before it reaches the wire or a
//!   backend.

mod dataflow;
mod rules;

pub mod diag;

pub use diag::{CycleProfile, Diagnostic, Report, Rule, Severity};

use crate::algorithms::program::Program;
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::isa::models::ModelKind;
use crate::isa::operation::{OpKind, Operation};
use anyhow::{bail, Result};

/// What to verify against: the control model, the gate set, and optional
/// whole-program context (declared inputs, reserved scratch columns).
#[derive(Debug, Clone)]
pub struct VerifyOptions {
    /// Control model whose operation-set and wire format the program must
    /// conform to.
    pub model: ModelKind,
    /// Gate set the target crossbar supports.
    pub gate_set: GateSet,
    /// Columns the program legitimately reads before writing (its operands).
    /// `Some` upgrades V040 (uninit read) from a note to an error for any
    /// read outside this set.
    pub inputs: Option<Vec<usize>>,
    /// Intra-partition indices reserved as legalizer scratch
    /// ([`crate::isa::lower::LegalizeConfig::scratch_intra`]); any program
    /// reference to them is a V043 error.
    pub scratch_intra: Option<(usize, usize)>,
}

impl VerifyOptions {
    pub fn new(model: ModelKind, gate_set: GateSet) -> Self {
        Self { model, gate_set, inputs: None, scratch_intra: None }
    }

    /// Declare the program's input columns (upgrades V040 to an error).
    pub fn with_inputs(mut self, inputs: Vec<usize>) -> Self {
        self.inputs = Some(inputs);
        self
    }

    /// Declare reserved legalizer scratch intra-partition indices (enables
    /// V043).
    pub fn with_scratch(mut self, scratch_intra: (usize, usize)) -> Self {
        self.scratch_intra = Some(scratch_intra);
        self
    }
}

/// Verify an operation stream: per-cycle rules (V00x–V03x) on every cycle,
/// then whole-program dataflow (V04x). Diagnostics are sorted by cycle.
pub fn verify_ops(name: &str, ops: &[Operation], geom: &Geometry, opts: &VerifyOptions) -> Report {
    let mut profile = CycleProfile::default();
    let mut diagnostics = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        match op.kind(geom) {
            OpKind::Serial => profile.serial += 1,
            OpKind::Parallel => profile.parallel += 1,
            OpKind::SemiParallel => profile.semi_parallel += 1,
            OpKind::Init => profile.init += 1,
        }
        rules::check_op(i, op, geom, opts, &mut diagnostics);
    }
    dataflow::check_dataflow(ops, geom, opts, &mut diagnostics);
    diagnostics.sort_by_key(|d| (d.cycle.is_none(), d.cycle));
    Report { program: name.to_string(), model: opts.model, cycles: ops.len(), profile, diagnostics }
}

/// Verify a built [`Program`] against `model`, using the program's own
/// geometry and gate set.
pub fn verify_program(program: &Program, model: ModelKind) -> Report {
    let opts = VerifyOptions::new(model, program.gate_set);
    verify_ops(&program.name, &program.ops, &program.geom, &opts)
}

/// The single-cycle check behind the pipeline's verify stage: run the
/// per-cycle rules (V00x–V03x) on one operation and fail on any
/// error-severity diagnostic. Warnings and notes pass.
pub fn check_cycle(op: &Operation, geom: &Geometry, opts: &VerifyOptions) -> Result<()> {
    let mut diagnostics = Vec::new();
    rules::check_op(0, op, geom, opts, &mut diagnostics);
    let errors: Vec<String> =
        diagnostics.iter().filter(|d| d.severity == Severity::Error).map(|d| format!("{}[{}] {}", d.severity, d.rule.code(), d.message)).collect();
    if !errors.is_empty() {
        bail!("verify stage rejected the operation: {}", errors.join("; "));
    }
    Ok(())
}
