//! Diagnostic types for the static verifier: severities, the rule catalog,
//! per-cycle classification profiles, and the [`Report`] a verification run
//! produces.
//!
//! Rule identifiers are stable (`V0xx`) and grouped by family:
//!
//! * `V00x` — structural rules (mirroring [`crate::isa::operation::Operation::validate`],
//!   but reported as diagnostics with cycle spans instead of a bare `Err`).
//! * `V01x` — intra-cycle hazards: column-level write-write / read-write
//!   overlap across partitions, and the mixed-direction policy.
//! * `V02x` — operation-set conformance per reduced control model
//!   (Section 3.1 / Section 4.1 criteria, reported *before* encode).
//! * `V03x` — wire representability: encodability under the model's message
//!   format and half-gate decoder roundtrip fidelity.
//! * `V04x` — whole-program dataflow: uninitialized reads, MAGIC init
//!   preconditions, dead writes, and legalizer scratch-column leaks.

use crate::isa::models::ModelKind;
use anyhow::{bail, Result};
use std::fmt;

/// Severity of a diagnostic. Ordered: `Info < Warning < Error`.
///
/// Only `Error`-severity diagnostics make a report unclean ([`Report::is_clean`])
/// and reject an operation at the pipeline's verify stage; warnings flag
/// hardware-fidelity or hygiene concerns the simulator tolerates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Informational note (e.g. a read of an undeclared input column).
    Info,
    /// Suspicious but executable (e.g. a missing MAGIC re-initialization).
    Warning,
    /// The program is malformed, hazardous, or silently mis-executes on the
    /// wire path.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "note",
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// The verifier's rule catalog. See `DESIGN.md` §Verifier for the full table
/// with example diagnostics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    /// V001: a cycle with no gates / no columns.
    EmptyCycle,
    /// V002: a column index outside the crossbar (`>= n`).
    ColumnRange,
    /// V003: a gate's output column aliases one of its inputs.
    OutputAliasesInput,
    /// V004: a gate outside the configured gate set, an init pseudo-gate in a
    /// gate cycle, or an arity mismatch.
    GateSetViolation,
    /// V005: two concurrent gates occupy overlapping partition intervals.
    SectionOverlap,
    /// V010: two gates write the same column in one cycle.
    WriteWriteHazard,
    /// V011: one gate writes a column another gate reads in the same cycle.
    ReadWriteHazard,
    /// V012: gates with opposing directions in one cycle — physically
    /// executable in disjoint sections, but inexpressible in the standard /
    /// minimal wire formats. Warning under unlimited, error under
    /// standard / minimal.
    MixedDirection,
    /// V020: more than one gate per cycle under the baseline (partition-free)
    /// model.
    BaselineMultiGate,
    /// V021: a gate whose inputs span two partitions (No Split-Input
    /// criterion, standard and minimal models).
    SplitInput,
    /// V022: gates with differing intra-partition index tuples (Identical
    /// Indices criterion, standard and minimal models).
    IdenticalIndices,
    /// V023: gates with differing partition distances (Uniform
    /// Partition-Distance criterion, minimal model).
    UniformDistance,
    /// V024: input partitions not periodic with period `T > d` (Periodic
    /// criterion, minimal model).
    Periodic,
    /// V030: the operation has no encoding in the model's wire format (and no
    /// more specific conformance rule explains why).
    NotEncodable,
    /// V031: the operation encodes, but the periphery decodes the message to
    /// *different* gates — the wire path would silently mis-execute.
    DecodeDivergence,
    /// V040: a column is read before any write and is not a declared program
    /// input.
    UninitRead,
    /// V041: a gate writes a column that was not initialized to one first —
    /// the MAGIC output precondition (the simulator computes the result
    /// regardless; real hardware would not).
    MissingInit,
    /// V042: a computed value is overwritten before any read.
    DeadWrite,
    /// V043: the program uses a column the legalizer configuration reserves
    /// as scratch (`LegalizeConfig::scratch_intra`).
    ScratchLeak,
}

impl Rule {
    /// Every rule, in catalog order.
    pub const ALL: [Rule; 19] = [
        Rule::EmptyCycle,
        Rule::ColumnRange,
        Rule::OutputAliasesInput,
        Rule::GateSetViolation,
        Rule::SectionOverlap,
        Rule::WriteWriteHazard,
        Rule::ReadWriteHazard,
        Rule::MixedDirection,
        Rule::BaselineMultiGate,
        Rule::SplitInput,
        Rule::IdenticalIndices,
        Rule::UniformDistance,
        Rule::Periodic,
        Rule::NotEncodable,
        Rule::DecodeDivergence,
        Rule::UninitRead,
        Rule::MissingInit,
        Rule::DeadWrite,
        Rule::ScratchLeak,
    ];

    /// Stable identifier, e.g. `"V012"`.
    pub fn code(&self) -> &'static str {
        match self {
            Rule::EmptyCycle => "V001",
            Rule::ColumnRange => "V002",
            Rule::OutputAliasesInput => "V003",
            Rule::GateSetViolation => "V004",
            Rule::SectionOverlap => "V005",
            Rule::WriteWriteHazard => "V010",
            Rule::ReadWriteHazard => "V011",
            Rule::MixedDirection => "V012",
            Rule::BaselineMultiGate => "V020",
            Rule::SplitInput => "V021",
            Rule::IdenticalIndices => "V022",
            Rule::UniformDistance => "V023",
            Rule::Periodic => "V024",
            Rule::NotEncodable => "V030",
            Rule::DecodeDivergence => "V031",
            Rule::UninitRead => "V040",
            Rule::MissingInit => "V041",
            Rule::DeadWrite => "V042",
            Rule::ScratchLeak => "V043",
        }
    }

    /// Human-readable slug, e.g. `"mixed-direction"`.
    pub fn name(&self) -> &'static str {
        match self {
            Rule::EmptyCycle => "empty-cycle",
            Rule::ColumnRange => "column-range",
            Rule::OutputAliasesInput => "output-aliases-input",
            Rule::GateSetViolation => "gate-set-violation",
            Rule::SectionOverlap => "section-overlap",
            Rule::WriteWriteHazard => "write-write-hazard",
            Rule::ReadWriteHazard => "read-write-hazard",
            Rule::MixedDirection => "mixed-direction",
            Rule::BaselineMultiGate => "baseline-multi-gate",
            Rule::SplitInput => "split-input",
            Rule::IdenticalIndices => "identical-indices",
            Rule::UniformDistance => "uniform-distance",
            Rule::Periodic => "non-periodic",
            Rule::NotEncodable => "not-encodable",
            Rule::DecodeDivergence => "decode-divergence",
            Rule::UninitRead => "uninit-read",
            Rule::MissingInit => "missing-init",
            Rule::DeadWrite => "dead-write",
            Rule::ScratchLeak => "scratch-leak",
        }
    }
}

/// One finding: a rule, a severity, an optional cycle span, and a message.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub rule: Rule,
    pub severity: Severity,
    /// Index of the offending cycle in the program's op stream (`None` for
    /// whole-program findings).
    pub cycle: Option<usize>,
    pub message: String,
}

impl Diagnostic {
    pub fn new(rule: Rule, severity: Severity, cycle: Option<usize>, message: String) -> Self {
        Self { rule, severity, cycle, message }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.cycle {
            Some(c) => write!(f, "{}[{}] cycle {}: {}", self.severity, self.rule.code(), c, self.message),
            None => write!(f, "{}[{}] {}", self.severity, self.rule.code(), self.message),
        }
    }
}

/// Per-cycle classification counts (Section 2.1 / Figure 2).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleProfile {
    pub serial: usize,
    pub parallel: usize,
    pub semi_parallel: usize,
    pub init: usize,
}

/// The result of verifying a program: classification profile plus the full
/// diagnostic list, sorted by cycle.
#[derive(Debug, Clone)]
pub struct Report {
    /// Name of the verified program (for rendering).
    pub program: String,
    /// Control model the program was checked against.
    pub model: ModelKind,
    /// Number of cycles (operations) in the program.
    pub cycles: usize,
    /// Per-cycle classification counts.
    pub profile: CycleProfile,
    /// All findings, sorted by cycle (whole-program findings last).
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn error_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn info_count(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Info).count()
    }

    /// `true` when the report contains no `Error`-severity diagnostics
    /// (warnings and notes do not make a program unclean).
    pub fn is_clean(&self) -> bool {
        self.error_count() == 0
    }

    /// `true` when any diagnostic with the given rule was emitted.
    pub fn has(&self, rule: Rule) -> bool {
        self.diagnostics.iter().any(|d| d.rule == rule)
    }

    /// Fail with a rendered summary of the error-severity diagnostics if the
    /// report is not clean.
    pub fn ensure_clean(&self) -> Result<()> {
        if self.is_clean() {
            return Ok(());
        }
        let mut msg = format!("verification of `{}` under the {} model failed: {} error(s)", self.program, self.model.name(), self.error_count());
        for d in self.diagnostics.iter().filter(|d| d.severity == Severity::Error).take(10) {
            msg.push_str("\n  ");
            msg.push_str(&d.to_string());
        }
        let omitted = self.error_count().saturating_sub(10);
        if omitted > 0 {
            msg.push_str(&format!("\n  ... and {omitted} more"));
        }
        bail!(msg)
    }

    /// Multi-line human-readable rendering (header + capped diagnostic list).
    pub fn render(&self) -> String {
        let p = &self.profile;
        let mut s = format!(
            "`{}` under {}: {} cycles ({} serial / {} parallel / {} semi-parallel / {} init), {} error(s), {} warning(s), {} note(s)",
            self.program,
            self.model.name(),
            self.cycles,
            p.serial,
            p.parallel,
            p.semi_parallel,
            p.init,
            self.error_count(),
            self.warning_count(),
            self.info_count(),
        );
        const CAP: usize = 50;
        for d in self.diagnostics.iter().take(CAP) {
            s.push_str("\n  ");
            s.push_str(&d.to_string());
        }
        if self.diagnostics.len() > CAP {
            s.push_str(&format!("\n  ... and {} more", self.diagnostics.len() - CAP));
        }
        s
    }
}
