//! Per-cycle rules: structural checks (V00x), intra-cycle hazards (V01x),
//! model conformance (V02x), and wire representability (V03x).
//!
//! The checks are layered: hazard and conformance rules only run on
//! structurally sound cycles (otherwise column/partition arithmetic is
//! meaningless), and the encode/decode dry run (V030/V031) only runs on
//! cycles with no structural errors (the codecs `debug_assert` on garbage).
//! V030 is a pure backstop — it is suppressed when a more specific rule
//! already explains why the cycle cannot reach the wire; V031 is always
//! reported because it is the *silent mis-execution* case: the message
//! encodes fine and the periphery executes different gates than intended.

use super::{Diagnostic, Rule, Severity, VerifyOptions};
use crate::crossbar::geometry::Geometry;
use crate::isa::encode;
use crate::isa::models::ModelKind;
use crate::isa::operation::{Direction, GateOp, Operation};
use crate::periphery;
use std::collections::BTreeMap;

/// Run every per-cycle rule on `op` (cycle index `cycle`), appending
/// diagnostics to `out`.
pub(crate) fn check_op(cycle: usize, op: &Operation, geom: &Geometry, opts: &VerifyOptions, out: &mut Vec<Diagnostic>) {
    let start = out.len();
    match op {
        Operation::Init { cols, .. } => {
            if cols.is_empty() {
                push(out, Rule::EmptyCycle, Severity::Error, cycle, "initialization writes no columns".into());
            }
            for &c in cols {
                if c >= geom.n {
                    push(out, Rule::ColumnRange, Severity::Error, cycle, format!("init column {c} out of range (n={})", geom.n));
                }
            }
            return;
        }
        Operation::Gates(gates) => {
            if structural(cycle, gates, geom, opts, out) {
                return;
            }
            hazards(cycle, gates, out);
            direction_policy(cycle, op, geom, opts, out);
            conformance(cycle, gates, geom, opts, out);
        }
    }
    wire_roundtrip(cycle, op, geom, opts, start, out);
}

fn push(out: &mut Vec<Diagnostic>, rule: Rule, severity: Severity, cycle: usize, message: String) {
    out.push(Diagnostic::new(rule, severity, Some(cycle), message));
}

/// V001–V004 (per gate) and V005 (section overlap). Returns `true` when a
/// structural error makes the remaining rules meaningless.
fn structural(cycle: usize, gates: &[GateOp], geom: &Geometry, opts: &VerifyOptions, out: &mut Vec<Diagnostic>) -> bool {
    if gates.is_empty() {
        push(out, Rule::EmptyCycle, Severity::Error, cycle, "gate cycle contains no gates".into());
        return true;
    }
    let mut bad = false;
    for (gi, g) in gates.iter().enumerate() {
        if g.gate.is_init() {
            push(out, Rule::GateSetViolation, Severity::Error, cycle, format!("gate {gi} is an init pseudo-gate {:?}; use an Init cycle", g.gate));
            bad = true;
        } else if let Err(e) = opts.gate_set.check(g.gate) {
            push(out, Rule::GateSetViolation, Severity::Error, cycle, format!("gate {gi}: {e}"));
            bad = true;
        }
        if g.ins.len() != g.gate.arity() {
            push(out, Rule::GateSetViolation, Severity::Error, cycle, format!("gate {gi} ({:?}) expects {} inputs, got {}", g.gate, g.gate.arity(), g.ins.len()));
            bad = true;
        }
        if g.out >= geom.n {
            push(out, Rule::ColumnRange, Severity::Error, cycle, format!("gate {gi} output column {} out of range (n={})", g.out, geom.n));
            bad = true;
        }
        for &c in &g.ins {
            if c >= geom.n {
                push(out, Rule::ColumnRange, Severity::Error, cycle, format!("gate {gi} input column {c} out of range (n={})", geom.n));
                bad = true;
            } else if c == g.out {
                push(out, Rule::OutputAliasesInput, Severity::Error, cycle, format!("gate {gi} output column {} aliases one of its inputs", g.out));
                bad = true;
            }
        }
    }
    if bad {
        return true;
    }
    let mut spans: Vec<(usize, usize)> = gates.iter().map(|g| g.span(geom)).collect();
    spans.sort_unstable();
    for w in spans.windows(2) {
        if w[0].1 >= w[1].0 {
            push(
                out,
                Rule::SectionOverlap,
                Severity::Error,
                cycle,
                format!("sections {:?} and {:?} overlap: concurrent gates must occupy disjoint partition intervals", w[0], w[1]),
            );
        }
    }
    false
}

/// V010/V011: column-level write-write and write-read overlap between
/// distinct gates of one cycle. Disjoint sections already imply disjoint
/// columns for valid cycles, so these fire together with V005 — but they
/// name the *data* hazard (which column, which gates) rather than the
/// physical one.
fn hazards(cycle: usize, gates: &[GateOp], out: &mut Vec<Diagnostic>) {
    let mut writers: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    let mut readers: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (gi, g) in gates.iter().enumerate() {
        writers.entry(g.out).or_default().push(gi);
        for &c in &g.ins {
            readers.entry(c).or_default().push(gi);
        }
    }
    for (col, ws) in &writers {
        if ws.len() > 1 {
            push(out, Rule::WriteWriteHazard, Severity::Error, cycle, format!("gates {ws:?} all write column {col} in the same cycle"));
        }
        if let Some(rs) = readers.get(col) {
            let others: Vec<usize> = rs.iter().copied().filter(|r| !ws.contains(r)).collect();
            if !others.is_empty() {
                push(
                    out,
                    Rule::ReadWriteHazard,
                    Severity::Error,
                    cycle,
                    format!("column {col} is written by gate {} and concurrently read by gate(s) {others:?}", ws[0]),
                );
            }
        }
    }
}

/// V012: the mixed-direction policy. Opposing directions in one cycle are
/// physically executable (the sections are disjoint) but have no wire
/// representation under the standard / minimal shared-direction formats —
/// so: warning under unlimited (representable, flagged for portability),
/// error under standard / minimal, not applicable under baseline
/// (single-gate cycles are enforced by V020 instead).
fn direction_policy(cycle: usize, op: &Operation, geom: &Geometry, opts: &VerifyOptions, out: &mut Vec<Diagnostic>) {
    if opts.model == ModelKind::Baseline || op.uniform_direction(geom).is_ok() {
        return;
    }
    let Operation::Gates(gates) = op else { return };
    let dirs: Vec<Option<Direction>> = gates.iter().map(|g| g.direction(geom)).collect();
    let severity = match opts.model {
        ModelKind::Unlimited => Severity::Warning,
        _ => Severity::Error,
    };
    let detail = if severity == Severity::Warning {
        "representable in the unlimited format but not portable to standard/minimal"
    } else {
        "the shared-direction wire format cannot express this cycle"
    };
    push(out, Rule::MixedDirection, severity, cycle, format!("gates with opposing partition directions in one cycle ({dirs:?}): {detail}"));
}

/// V020–V024: the reduced operation-set criteria of Sections 3.1 and 4.1,
/// mirroring [`ModelKind::check`] but with per-gate spans and rule ids.
fn conformance(cycle: usize, gates: &[GateOp], geom: &Geometry, opts: &VerifyOptions, out: &mut Vec<Diagnostic>) {
    match opts.model {
        ModelKind::Baseline => {
            if gates.len() > 1 {
                push(
                    out,
                    Rule::BaselineMultiGate,
                    Severity::Error,
                    cycle,
                    format!("{} concurrent gates, but the baseline (partition-free) model executes one gate per cycle", gates.len()),
                );
            }
        }
        ModelKind::Unlimited => {}
        ModelKind::Standard | ModelKind::Minimal => {
            let mut split = false;
            for (gi, g) in gates.iter().enumerate() {
                if g.input_partition(geom).is_none() {
                    let ps: Vec<usize> = g.ins.iter().map(|&c| geom.partition_of(c)).collect();
                    push(
                        out,
                        Rule::SplitInput,
                        Severity::Error,
                        cycle,
                        format!("gate {gi} inputs span partitions {ps:?} (No Split-Input criterion)"),
                    );
                    split = true;
                }
            }
            let tuple = |g: &GateOp| -> (usize, usize, usize) {
                (geom.intra(g.ins[0]), geom.intra(*g.ins.get(1).unwrap_or(&g.ins[0])), geom.intra(g.out))
            };
            let first = tuple(&gates[0]);
            if let Some((gi, g)) = gates.iter().enumerate().find(|(_, g)| tuple(g) != first) {
                push(
                    out,
                    Rule::IdenticalIndices,
                    Severity::Error,
                    cycle,
                    format!("gate {gi} uses intra-partition indices {:?} but gate 0 uses {first:?} (Identical Indices criterion)", tuple(g)),
                );
            }
            if opts.model == ModelKind::Minimal && !split {
                minimal_pattern(cycle, gates, geom, out);
            }
        }
    }
}

/// V023/V024: the minimal model's Uniform Partition-Distance and Periodic
/// (`T > d`) criteria — the preconditions of the range generator.
fn minimal_pattern(cycle: usize, gates: &[GateOp], geom: &Geometry, out: &mut Vec<Diagnostic>) {
    // Callers guarantee no split-input gates, so distance() is always Some.
    let dists: Vec<usize> = gates.iter().filter_map(|g| g.distance(geom)).map(|d| d.unsigned_abs()).collect();
    let d0 = dists[0];
    if let Some((gi, d)) = dists.iter().enumerate().find(|(_, d)| **d != d0) {
        push(
            out,
            Rule::UniformDistance,
            Severity::Error,
            cycle,
            format!("gate {gi} has partition distance {d} but gate 0 has {d0} (Uniform Partition-Distance criterion)"),
        );
    }
    let mut inputs: Vec<usize> = gates.iter().filter_map(|g| g.input_partition(geom)).collect();
    inputs.sort_unstable();
    for w in inputs.windows(2) {
        if w[0] == w[1] {
            push(out, Rule::Periodic, Severity::Error, cycle, format!("two gates share input partition {} (Periodic criterion)", w[0]));
            return;
        }
    }
    if inputs.len() >= 2 {
        let t = inputs[1] - inputs[0];
        if t <= d0 {
            push(
                out,
                Rule::Periodic,
                Severity::Error,
                cycle,
                format!("period T={t} does not exceed distance d={d0} (Periodic criterion: consecutive gates would collide)"),
            );
        }
        for w in inputs.windows(2) {
            if w[1] - w[0] != t {
                push(
                    out,
                    Rule::Periodic,
                    Severity::Error,
                    cycle,
                    format!("aperiodic input partitions {inputs:?}: gap {} differs from period T={t} — the range generator would expand this message to different gates", w[1] - w[0]),
                );
                break;
            }
        }
    }
}

/// V030/V031: dry-run the model's encoder and the half-gates periphery on
/// the cycle and compare the reconstructed operation against the intent.
fn wire_roundtrip(cycle: usize, op: &Operation, geom: &Geometry, opts: &VerifyOptions, start: usize, out: &mut Vec<Diagnostic>) {
    if matches!(op, Operation::Init { .. }) {
        return; // init writes bypass the gate wire formats
    }
    let had_error = out[start..].iter().any(|d| d.severity == Severity::Error);
    // The cycle's wire class under the backend's gate set: a cycle mixing
    // classes (e.g. XOR + NOR) or using a gate with no wire class (Min3)
    // has no message at all in the typed format.
    let class = match encode::cycle_wire_class(op, opts.gate_set) {
        Ok(class) => class,
        Err(e) => {
            if !had_error {
                push(out, Rule::NotEncodable, Severity::Error, cycle, format!("no encoding in the {} wire format: {e}", opts.model.name()));
            }
            return;
        }
    };
    match encode::to_message(opts.model, op, geom) {
        Err(e) => {
            if !had_error {
                push(out, Rule::NotEncodable, Severity::Error, cycle, format!("no encoding in the {} wire format: {e}", opts.model.name()));
            }
        }
        Ok(msg) => match periphery::reconstruct_typed(class, &msg, geom) {
            Err(e) => {
                push(out, Rule::DecodeDivergence, Severity::Error, cycle, format!("the encoded message fails to decode: {e}"));
            }
            Ok(rec) => {
                if rec.normalized() != op.normalized() {
                    push(
                        out,
                        Rule::DecodeDivergence,
                        Severity::Error,
                        cycle,
                        format!(
                            "wire roundtrip diverges under the {} format: the periphery would execute {} gate(s) instead of the intended {} — silent mis-execution",
                            opts.model.name(),
                            rec.gate_count(),
                            op.gate_count(),
                        ),
                    );
                }
            }
        },
    }
}
