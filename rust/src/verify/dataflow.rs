//! Whole-program dataflow rules (V04x): a per-column abstract state machine
//! walked over the cycle stream.
//!
//! Within a gate cycle all reads happen before all writes (the crossbar
//! latches input voltages before the output memristors switch), so each
//! cycle processes its reads first and its writes second.

use super::{Diagnostic, Rule, Severity, VerifyOptions};
use crate::crossbar::geometry::Geometry;
use crate::isa::operation::Operation;
use std::collections::HashSet;

/// Abstract per-column state.
#[derive(Clone, Copy, PartialEq)]
enum Cell {
    /// Never written by the program.
    Untouched,
    /// Initialized by an `Init` cycle (the MAGIC write precondition holds).
    Ready,
    /// Written by a gate at `cycle`; `read` tracks whether any later cycle
    /// consumed the value.
    Computed { cycle: usize, read: bool },
}

pub(crate) fn check_dataflow(ops: &[Operation], geom: &Geometry, opts: &VerifyOptions, out: &mut Vec<Diagnostic>) {
    scratch_leaks(ops, geom, opts, out);
    let declared: Option<HashSet<usize>> = opts.inputs.as_ref().map(|v| v.iter().copied().collect());
    // Without a declared input set any never-written column could be a
    // legitimate operand loaded at runtime, so V040 is only a note.
    let uninit_severity = if declared.is_some() { Severity::Error } else { Severity::Info };
    let mut cells = vec![Cell::Untouched; geom.n];
    let mut reported_uninit: HashSet<usize> = HashSet::new();
    for (i, op) in ops.iter().enumerate() {
        match op {
            Operation::Init { cols, .. } => {
                for &c in cols {
                    if c >= geom.n {
                        continue; // V002 already reported
                    }
                    if let Cell::Computed { cycle, read: false } = cells[c] {
                        out.push(Diagnostic::new(
                            Rule::DeadWrite,
                            Severity::Warning,
                            Some(i),
                            format!("column {c} computed at cycle {cycle} is re-initialized before any read"),
                        ));
                    }
                    cells[c] = Cell::Ready;
                }
            }
            Operation::Gates(gates) => {
                for g in gates {
                    for &c in &g.ins {
                        if c >= geom.n {
                            continue;
                        }
                        match cells[c] {
                            Cell::Untouched => {
                                let undeclared = match &declared {
                                    Some(d) => !d.contains(&c),
                                    None => true,
                                };
                                if undeclared && reported_uninit.insert(c) {
                                    out.push(Diagnostic::new(
                                        Rule::UninitRead,
                                        uninit_severity,
                                        Some(i),
                                        format!("column {c} is read but never written and not declared as a program input"),
                                    ));
                                }
                            }
                            Cell::Computed { cycle, .. } => cells[c] = Cell::Computed { cycle, read: true },
                            Cell::Ready => {}
                        }
                    }
                }
                for g in gates {
                    let c = g.out;
                    if c >= geom.n {
                        continue;
                    }
                    match cells[c] {
                        Cell::Ready => {}
                        Cell::Untouched => out.push(Diagnostic::new(
                            Rule::MissingInit,
                            Severity::Warning,
                            Some(i),
                            format!("gate output column {c} was never initialized (MAGIC requires an init-to-1 cycle before a gate writes)"),
                        )),
                        Cell::Computed { cycle, read } => {
                            if !read {
                                out.push(Diagnostic::new(
                                    Rule::DeadWrite,
                                    Severity::Warning,
                                    Some(i),
                                    format!("column {c} computed at cycle {cycle} is overwritten before any read"),
                                ));
                            }
                            out.push(Diagnostic::new(
                                Rule::MissingInit,
                                Severity::Warning,
                                Some(i),
                                format!("gate output column {c} reused without re-initialization (last written at cycle {cycle})"),
                            ));
                        }
                    }
                    cells[c] = Cell::Computed { cycle: i, read: false };
                }
            }
        }
    }
}

/// V043: the program references a column whose intra-partition index the
/// legalizer configuration reserves as scratch — legalizing such a program
/// would clobber live data.
fn scratch_leaks(ops: &[Operation], geom: &Geometry, opts: &VerifyOptions, out: &mut Vec<Diagnostic>) {
    let Some((s1, s2)) = opts.scratch_intra else { return };
    let mut reported: HashSet<usize> = HashSet::new();
    for (i, op) in ops.iter().enumerate() {
        let mut cols: Vec<usize> = Vec::new();
        match op {
            Operation::Init { cols: c, .. } => cols.extend_from_slice(c),
            Operation::Gates(gates) => {
                for g in gates {
                    cols.push(g.out);
                    cols.extend_from_slice(&g.ins);
                }
            }
        }
        for c in cols {
            if c < geom.n && (geom.intra(c) == s1 || geom.intra(c) == s2) && reported.insert(c) {
                out.push(Diagnostic::new(
                    Rule::ScratchLeak,
                    Severity::Error,
                    Some(i),
                    format!(
                        "column {c} (partition {}, intra index {}) is reserved as legalizer scratch (scratch_intra = ({s1}, {s2})); legalization would clobber it",
                        geom.partition_of(c),
                        geom.intra(c),
                    ),
                ));
            }
        }
    }
}
