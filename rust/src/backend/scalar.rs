//! The naive scalar reference backend: one `bool` per memristor, one
//! explicit loop per row — deliberately the dumbest possible realization of
//! the operation semantics, kept free of every optimization the bit-packed
//! simulator carries (word packing, tail masks, trusted fast paths).
//!
//! Its only job is to be *obviously correct* so it can serve as the
//! differential-testing oracle for every other [`PimBackend`]
//! (`tests/proptests.rs` P10/P11): if the two disagree, the clever one is
//! wrong.

use crate::backend::PimBackend;
use crate::crossbar::crossbar::Metrics;
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::operation::Operation;
use anyhow::Result;

/// A scalar (per-bit) crossbar model.
#[derive(Debug, Clone)]
pub struct ScalarCrossbar {
    geom: Geometry,
    gate_set: GateSet,
    /// Plain row-major booleans: `state[row][col]`.
    state: Vec<Vec<bool>>,
    metrics: Metrics,
}

impl ScalarCrossbar {
    pub fn new(geom: Geometry, gate_set: GateSet) -> Self {
        Self { geom, gate_set, state: vec![vec![false; geom.n]; geom.rows], metrics: Metrics::default() }
    }

    /// Read one cell (test convenience).
    pub fn get(&self, row: usize, col: usize) -> bool {
        self.state[row][col]
    }
}

impl PimBackend for ScalarCrossbar {
    fn name(&self) -> &'static str {
        "scalar-reference"
    }

    fn geom(&self) -> Geometry {
        self.geom
    }

    fn gate_set(&self) -> GateSet {
        self.gate_set
    }

    fn load_state(&mut self, m: &BitMatrix) -> Result<()> {
        crate::backend::check_state_shape(&self.geom, m)?;
        for (r, row) in self.state.iter_mut().enumerate() {
            for (c, cell) in row.iter_mut().enumerate() {
                *cell = m.get(r, c);
            }
        }
        Ok(())
    }

    fn state_bits(&self) -> Result<BitMatrix> {
        let mut m = BitMatrix::new(self.geom.rows, self.geom.n);
        for (r, row) in self.state.iter().enumerate() {
            for (c, &v) in row.iter().enumerate() {
                if v {
                    m.set(r, c, true);
                }
            }
        }
        Ok(m)
    }

    fn execute(&mut self, op: &Operation) -> Result<()> {
        op.validate(&self.geom, self.gate_set)?;
        match op {
            Operation::Init { cols, value } => {
                for row in self.state.iter_mut() {
                    for &c in cols {
                        if row[c] != *value {
                            self.metrics.switch_events += 1;
                            row[c] = *value;
                        }
                    }
                }
                self.metrics.cycles += 1;
                self.metrics.init_cycles += 1;
            }
            Operation::Gates(gates) => {
                // Concurrent gates occupy pairwise-disjoint sections, so no
                // column is both read and written within the cycle and the
                // per-gate order is immaterial.
                for g in gates {
                    for r in 0..self.geom.rows {
                        let ins: Vec<bool> = g.ins.iter().map(|&c| self.state[r][c]).collect();
                        let v = g.gate.eval_bool(&ins);
                        if self.state[r][g.out] != v {
                            self.metrics.switch_events += 1;
                            self.state[r][g.out] = v;
                        }
                    }
                }
                self.metrics.cycles += 1;
                self.metrics.gate_cycles += 1;
                self.metrics.gate_events += gates.len() as u64;
            }
        }
        Ok(())
    }

    fn metrics(&self) -> Metrics {
        self.metrics
    }

    fn reset_metrics(&mut self) {
        self.metrics = Metrics::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::operation::GateOp;

    #[test]
    fn nor_semantics_and_switch_count() {
        let geom = Geometry::new(64, 1, 8).unwrap();
        let mut sc = ScalarCrossbar::new(geom, GateSet::NotNor);
        // a = 0, b = 0 in every row; out initialized to 1 -> NOR = 1, no flips.
        sc.execute(&Operation::init1(vec![2])).unwrap();
        assert_eq!(sc.metrics().switch_events, 8);
        sc.execute(&Operation::serial(GateOp::nor(0, 1, 2))).unwrap();
        assert_eq!(sc.metrics().switch_events, 8, "NOR(0,0)=1 flips nothing");
        for r in 0..8 {
            assert!(sc.get(r, 2));
        }
        assert_eq!(sc.metrics().cycles, 2);
        assert_eq!(sc.metrics().gate_cycles, 1);
    }

    #[test]
    fn state_roundtrip() {
        let geom = Geometry::new(64, 1, 70).unwrap(); // non-multiple-of-64 rows
        let mut m = BitMatrix::new(70, 64);
        m.fill_random(13);
        let mut sc = ScalarCrossbar::new(geom, GateSet::NotNor);
        sc.load_state(&m).unwrap();
        assert_eq!(sc.state_bits().unwrap(), m);
    }

    #[test]
    fn rejects_unsupported_gate() {
        let geom = Geometry::new(64, 1, 4).unwrap();
        let mut sc = ScalarCrossbar::new(geom, GateSet::NotNor);
        let op = Operation::serial(GateOp { gate: crate::crossbar::gate::GateType::And, ins: vec![0, 1], out: 2 });
        assert!(sc.execute(&op).is_err());
    }
}
