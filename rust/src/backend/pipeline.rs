//! The composable execution pipeline: the one path every program takes to
//! reach a backend.
//!
//! A pipeline is an ordered list of control [`Stage`]s in front of a
//! [`PimBackend`]:
//!
//! ```text
//!   Program ──ops──▶ Legalize(cfg) ──ops──▶ Verify(model) ──ops──▶
//!            Encode(model) ──wire bits──▶
//!            PeripheryDecode(model) ──reconstructed ops──▶ backend
//! ```
//!
//! Every stage is optional; the valid compositions are any sequence of
//! `Legalize` / `Verify` stages followed by an optional matched
//! `Encode → PeripheryDecode` pair (enforced at construction, so a
//! mis-ordered pipeline fails fast instead of at the first operation). The
//! three common shapes have shorthand constructors:
//!
//! * [`ExecPipeline::direct`] — abstract operations straight to the backend.
//! * [`ExecPipeline::wire`] — statically verify each cycle against the
//!   model's rule catalog (`verify::`), encode it to its bit-exact wire
//!   message, decode through the periphery model, execute; control traffic
//!   is metered at the decode boundary (the production path).
//! * [`ExecPipeline::full`] — additionally legalize every operation for the
//!   model first (Section 5's "alternatives").
//!
//! The controller-side stages (legalize + encode) can be applied once with
//! [`ExecPipeline::prepare`], yielding a [`PreparedProgram`] that streams to
//! the crossbar-side stages repeatedly — the coordinator encodes a compiled
//! program a single time and replays it for every batch (see DESIGN.md
//! §Perf). A wire-pipeline `prepare` additionally decodes the stream once
//! into a trusted op cache, so [`ExecPipeline::run_prepared`] under the
//! default [`ReplayMode::Decoded`] skips the per-replay periphery decode and
//! hands the whole batch to [`PimBackend::execute_trusted_batch`] — the
//! "pay for control once, then go wide" replay fast path (DESIGN.md
//! §Replay fast path). [`ReplayMode::Wire`] forces the full decode path.

use crate::backend::PimBackend;
use crate::crossbar::crossbar::{init_message_bits, Metrics};
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::isa::encode::{self, BitVec};
use crate::isa::lower::{legalize_op, LegalizeConfig, LegalizeStats};
use crate::isa::models::ModelKind;
use crate::isa::operation::Operation;
use crate::periphery;
use crate::verify::{self, VerifyOptions};
use anyhow::{bail, ensure, Result};

/// One control stage of an execution pipeline.
#[derive(Debug, Clone, Copy)]
pub enum Stage {
    /// Rewrite operations the model cannot express into supported
    /// alternatives (Section 5).
    Legalize { model: ModelKind, cfg: LegalizeConfig },
    /// Statically check each cycle against the verifier's per-cycle rule
    /// catalog for `model` (structural, hazard, conformance and wire
    /// representability rules — see [`crate::verify`]); any error-severity
    /// diagnostic rejects the operation before it reaches the wire or the
    /// backend. Warnings pass.
    Verify(ModelKind),
    /// Controller side: encode each gate cycle as the model's bit-exact wire
    /// message; initialization writes travel on the write path.
    Encode(ModelKind),
    /// Crossbar side: decode wire traffic through the periphery model and
    /// reconstruct the executed gates. Control traffic is metered here.
    PeripheryDecode(ModelKind),
}

/// What flows between stages: abstract operations upstream of the encoder,
/// wire traffic between encoder and periphery.
#[derive(Debug, Clone)]
enum Item {
    Op(Operation),
    /// A gate cycle's control message.
    Message(BitVec),
    /// An initialization write command (travels on the write path; charged
    /// [`init_message_bits`] of control traffic at the decode boundary).
    InitWrite { cols: Vec<usize>, value: bool },
}

/// A borrowed view of an [`Item`] at the decode boundary, so the consumers
/// ([`ExecPipeline::run_prepared`], [`ExecPipeline::run_wire`]) never clone
/// staged payloads per replay.
enum ItemRef<'a> {
    Op(&'a Operation),
    Message(&'a BitVec),
    InitWrite { cols: &'a [usize], value: bool },
}

impl Item {
    fn borrowed(&self) -> ItemRef<'_> {
        match self {
            Item::Op(op) => ItemRef::Op(op),
            Item::Message(bits) => ItemRef::Message(bits),
            Item::InitWrite { cols, value } => ItemRef::InitWrite { cols, value: *value },
        }
    }
}

/// How [`ExecPipeline::run_prepared`] replays a prepared program.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplayMode {
    /// The fast path: replay the trusted operations decoded once at
    /// [`ExecPipeline::prepare`] time, charging the cached control-traffic
    /// cost per run (bit-identical states and metrics to [`ReplayMode::Wire`]
    /// — proptest P14). Falls back to the wire path when the pipeline or
    /// backend does not match the cache (see DESIGN.md §Replay fast path).
    #[default]
    Decoded,
    /// Re-decode the full wire stream on every replay — the escape hatch the
    /// fuzz and differential tests use to force the periphery decode path.
    Wire,
}

/// Counters accumulated at the pipeline's stage boundaries. Backend-side
/// counters (cycles, gates, switching) live in the backend's [`Metrics`];
/// [`ExecPipeline::metrics`] merges the two views.
///
/// ## Replay metering contract
///
/// [`ExecPipeline::prepare`] charges `ops_in` exactly once — controller-side
/// work happens once per program, never on replay. Each
/// [`ExecPipeline::run_prepared`] call then grows `ops_to_backend`,
/// `control_bits` and `messages` by the same per-replay amounts in both
/// [`ReplayMode`]s: the decoded fast path charges the control cost cached at
/// prepare time, so N replays meter exactly N × the wire-path deltas
/// (regression-tested in `n_replays_meter_exactly_n_times_the_wire_deltas`).
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineStats {
    /// Operations submitted by programs (pre-legalization cycles).
    pub ops_in: usize,
    /// Operations delivered to the backend (post-legalization cycles).
    pub ops_to_backend: usize,
    /// Legalizer statistics (all Legalize stages combined).
    pub legalize: LegalizeStats,
    /// Control-message traffic through the decode boundary, in bits.
    pub control_bits: u64,
    /// Control messages (gate messages + write commands) received.
    pub messages: u64,
}

/// A program with its controller-side stages already applied, ready to
/// stream to the crossbar-side stages any number of times. Run it with
/// [`ExecPipeline::run_prepared`] on a pipeline with the same stage
/// configuration it was prepared on (a mismatch fails cleanly at the decode
/// or backend boundary).
#[derive(Debug, Clone)]
pub struct PreparedProgram {
    items: Vec<Item>,
    /// The decode-once trusted op cache, built at [`ExecPipeline::prepare`]
    /// time when the pipeline ends in a periphery-decode stage.
    cache: Option<DecodedCache>,
}

/// The decode-once replay cache: every wire item of a prepared program run
/// through `encode::decode` + `periphery::reconstruct` a single time, plus
/// the control-traffic cost one full replay of the stream meters at the
/// decode boundary. The cache is only trusted for the exact (model,
/// geometry) it was decoded under; [`ExecPipeline::run_prepared`] falls back
/// to the wire path on any mismatch.
#[derive(Debug, Clone)]
struct DecodedCache {
    model: ModelKind,
    geom: Geometry,
    ops: Vec<Operation>,
    /// Control bits one replay of the stream carries.
    control_bits: u64,
    /// Control messages (gate messages + write commands) per replay.
    messages: u64,
}

impl PreparedProgram {
    /// Number of prepared cycles.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// True when the decode-once trusted op cache is present (the program
    /// was prepared on a wire pipeline), so [`ReplayMode::Decoded`] replays
    /// skip the per-replay periphery decode.
    pub fn is_decoded(&self) -> bool {
        self.cache.is_some()
    }
}

/// An execution pipeline borrowing a backend.
pub struct ExecPipeline<'a> {
    stages: Vec<Stage>,
    backend: &'a mut dyn PimBackend,
    /// Operations reaching the backend were reconstructed by the periphery
    /// decode stage — validated by construction, so they execute on the
    /// trusted path.
    decoded: bool,
    /// How [`ExecPipeline::run_prepared`] replays (decoded cache vs full
    /// wire re-decode).
    replay_mode: ReplayMode,
    /// Word-range executor threads the backend may use per decoded replay.
    replay_threads: usize,
    stats: PipelineStats,
}

impl<'a> ExecPipeline<'a> {
    /// Build a pipeline, validating the stage composition: any sequence of
    /// `Legalize` / `Verify` stages optionally followed by a matched
    /// `Encode → PeripheryDecode` pair.
    pub fn new(stages: Vec<Stage>, backend: &'a mut dyn PimBackend) -> Result<Self> {
        let mut i = 0;
        while i < stages.len() && matches!(stages[i], Stage::Legalize { .. } | Stage::Verify(_)) {
            i += 1;
        }
        match &stages[i..] {
            [] => {}
            [Stage::Encode(e), Stage::PeripheryDecode(d)] => {
                ensure!(e == d, "encode model {} and decode model {} differ", e.name(), d.name());
            }
            rest => bail!(
                "invalid stage composition {rest:?}: expected (Legalize | Verify)* followed by an optional Encode -> PeripheryDecode pair"
            ),
        }
        let decoded = matches!(stages.last(), Some(Stage::PeripheryDecode(_)));
        Ok(Self {
            stages,
            backend,
            decoded,
            replay_mode: ReplayMode::Decoded,
            replay_threads: 1,
            stats: PipelineStats::default(),
        })
    }

    /// Abstract operations straight to the backend.
    pub fn direct(backend: &'a mut dyn PimBackend) -> Self {
        Self::new(Vec::new(), backend).expect("an empty stage list is always valid")
    }

    /// The production control path: verify → encode → periphery decode →
    /// execute, with control-traffic metering. The verify stage rejects
    /// hazardous or non-conforming cycles — including ones the encoder would
    /// accept but the periphery would silently decode to different gates —
    /// before they reach the wire.
    pub fn wire(model: ModelKind, backend: &'a mut dyn PimBackend) -> Self {
        Self::new(vec![Stage::Verify(model), Stage::Encode(model), Stage::PeripheryDecode(model)], backend)
            .expect("the wire stage list is always valid")
    }

    /// Legalize for `model`, then run the verified wire path.
    pub fn full(model: ModelKind, cfg: LegalizeConfig, backend: &'a mut dyn PimBackend) -> Self {
        Self::new(
            vec![Stage::Legalize { model, cfg }, Stage::Verify(model), Stage::Encode(model), Stage::PeripheryDecode(model)],
            backend,
        )
        .expect("the full stage list is always valid")
    }

    /// The backend behind the pipeline.
    pub fn backend(&self) -> &dyn PimBackend {
        &*self.backend
    }

    pub fn backend_mut(&mut self) -> &mut dyn PimBackend {
        &mut *self.backend
    }

    /// The stage composition.
    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    /// Choose how [`ExecPipeline::run_prepared`] replays prepared programs
    /// (default [`ReplayMode::Decoded`]). Fuzz and differential tests force
    /// [`ReplayMode::Wire`] to exercise the full periphery decode path.
    pub fn set_replay_mode(&mut self, mode: ReplayMode) {
        self.replay_mode = mode;
    }

    /// The configured replay mode.
    pub fn replay_mode(&self) -> ReplayMode {
        self.replay_mode
    }

    /// Word-range executor threads the backend may use per decoded replay
    /// (clamped to at least 1; the backend clamps to its word count).
    pub fn set_replay_threads(&mut self, threads: usize) {
        self.replay_threads = threads.max(1);
    }

    /// The configured word-range thread count.
    pub fn replay_threads(&self) -> usize {
        self.replay_threads
    }

    /// Pipeline-boundary counters accumulated so far.
    pub fn stats(&self) -> PipelineStats {
        self.stats
    }

    /// The merged architectural view: the backend's execution counters plus
    /// the control traffic metered at the pipeline's decode boundary.
    pub fn metrics(&self) -> Metrics {
        let mut m = self.backend.metrics();
        m.control_bits += self.stats.control_bits;
        m.messages += self.stats.messages;
        m
    }

    /// Reset both the pipeline counters and the backend counters.
    pub fn reset_metrics(&mut self) {
        self.stats = PipelineStats::default();
        self.backend.reset_metrics();
    }

    /// Index of the first crossbar-side stage (everything before it is
    /// controller-side and can be pre-applied by [`ExecPipeline::prepare`]).
    fn front_len(&self) -> usize {
        self.stages.len() - usize::from(self.decoded)
    }

    /// The decode model, when the pipeline ends in a periphery-decode stage.
    fn decode_model(&self) -> Option<ModelKind> {
        match self.stages.last() {
            Some(Stage::PeripheryDecode(m)) => Some(*m),
            _ => None,
        }
    }

    /// Apply the controller-side stages in `range` to `items` (stages are
    /// `Copy`, so the index walk sidesteps borrowing `self.stages` across
    /// the `&mut self` stage application).
    fn apply_stages(&mut self, range: std::ops::Range<usize>, mut items: Vec<Item>, geom: &Geometry, gate_set: GateSet) -> Result<Vec<Item>> {
        let mut i = range.start;
        while i < range.end {
            let stage = self.stages[i];
            items = self.apply_stage(stage, items, geom, gate_set)?;
            i += 1;
        }
        Ok(items)
    }

    fn apply_stage(&mut self, stage: Stage, items: Vec<Item>, geom: &Geometry, gate_set: GateSet) -> Result<Vec<Item>> {
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            match (stage, item) {
                (Stage::Legalize { model, cfg }, Item::Op(op)) => {
                    for legal in legalize_op(&op, model, geom, gate_set, &cfg, &mut self.stats.legalize)? {
                        out.push(Item::Op(legal));
                    }
                }
                (Stage::Verify(model), Item::Op(op)) => {
                    verify::check_cycle(&op, geom, &VerifyOptions::new(model, gate_set))?;
                    out.push(Item::Op(op));
                }
                (Stage::Encode(model), Item::Op(op)) => out.push(Self::encode_item(model, &op, geom, gate_set)?),
                (Stage::PeripheryDecode(_), _) => {
                    bail!("periphery decode is a crossbar-side stage; it is consumed at the decode boundary, not applied in the controller-side stage walk")
                }
                (Stage::Legalize { .. } | Stage::Verify(_) | Stage::Encode(_), other) => {
                    bail!("stage {stage:?} expects abstract operations, got already-encoded {other:?}")
                }
            }
        }
        Ok(out)
    }

    /// Consume one staged item by reference at the crossbar boundary: the
    /// decode stage (when present) meters control traffic and reconstructs
    /// the executed gates, then the backend runs the cycle. This is the
    /// single decode-and-execute path shared by [`ExecPipeline::run_op`],
    /// [`ExecPipeline::run_prepared`] and [`ExecPipeline::run_wire`] — no
    /// per-replay cloning of the prepared stream.
    fn consume_item(&mut self, item: ItemRef<'_>, geom: &Geometry) -> Result<()> {
        match (self.decode_model(), item) {
            (Some(model), ItemRef::Message(bits)) => {
                self.stats.control_bits += bits.len() as u64;
                self.stats.messages += 1;
                let gate_set = self.backend.gate_set();
                let (class, msg) = encode::decode_with(model, bits, geom, gate_set)?;
                let op = periphery::reconstruct_typed(class, &msg, geom)?;
                self.stats.ops_to_backend += 1;
                self.backend.execute_trusted(&op)
            }
            (Some(_), ItemRef::InitWrite { cols, value }) => {
                self.stats.control_bits += init_message_bits(geom) as u64;
                self.stats.messages += 1;
                self.stats.ops_to_backend += 1;
                // Write commands are not covered by the periphery
                // reconstruction guarantee, so they take the validating
                // path: a malformed write must be rejected before any cell
                // is touched, identically on every backend.
                self.backend.execute(&Operation::Init { cols: cols.to_vec(), value })
            }
            (Some(_), ItemRef::Op(_)) => {
                bail!("periphery decode received an abstract operation; it must follow an encode stage")
            }
            (None, ItemRef::Op(op)) => {
                self.stats.ops_to_backend += 1;
                self.backend.execute(op)
            }
            (None, _) => {
                bail!("pipeline ended with undecoded wire traffic; a PeripheryDecode stage must precede the backend")
            }
        }
    }

    /// Encode one borrowed operation for the wire (the legalize-free fast
    /// path of [`ExecPipeline::run_op`] — no staging clone per cycle). The
    /// backend's gate set selects the wire format: NOT/NOR emits the paper's
    /// untyped messages bit-for-bit, richer sets prepend the per-cycle
    /// gate-type field (see [`encode::encode_with`]).
    fn encode_item(model: ModelKind, op: &Operation, geom: &Geometry, gate_set: GateSet) -> Result<Item> {
        Ok(match op {
            Operation::Init { cols, value } => Item::InitWrite { cols: cols.clone(), value: *value },
            Operation::Gates(_) => Item::Message(encode::encode_with(model, op, geom, gate_set)?),
        })
    }

    /// Push one operation through every stage to the backend.
    pub fn run_op(&mut self, op: &Operation) -> Result<()> {
        self.stats.ops_in += 1;
        // Stage-free pipelines are the simulator hot path: hand the
        // operation to the backend by reference, with no staging allocation.
        if self.stages.is_empty() {
            self.stats.ops_to_backend += 1;
            return self.backend.execute(op);
        }
        let geom = self.backend.geom();
        // A pure wire pipeline (optionally fronted by its verify stage)
        // encodes straight from the borrowed op — the production path
        // allocates only the message itself.
        let wire_model = match (self.front_len(), self.stages[0]) {
            (1, Stage::Encode(model)) => Some((None, model)),
            (2, Stage::Verify(v)) => match self.stages[1] {
                Stage::Encode(model) => Some((Some(v), model)),
                _ => None,
            },
            _ => None,
        };
        if let Some((verify_model, model)) = wire_model {
            if let Some(v) = verify_model {
                verify::check_cycle(op, &geom, &VerifyOptions::new(v, self.backend.gate_set()))?;
            }
            let item = Self::encode_item(model, op, &geom, self.backend.gate_set())?;
            return self.consume_item(item.borrowed(), &geom);
        }
        let gate_set = self.backend.gate_set();
        let staged = self.apply_stages(0..self.front_len(), vec![Item::Op(op.clone())], &geom, gate_set)?;
        for item in &staged {
            self.consume_item(item.borrowed(), &geom)?;
        }
        Ok(())
    }

    /// Push a sequence of operations through the pipeline.
    /// [`crate::algorithms::program::Program::execute`] is the usual entry.
    pub fn run_ops(&mut self, ops: &[Operation]) -> Result<()> {
        for op in ops {
            self.run_op(op)?;
        }
        Ok(())
    }

    /// Apply the controller-side stages (legalize + encode) once. On a wire
    /// pipeline this additionally runs every encoded item through the
    /// periphery decode a single time, attaching the decode-once trusted op
    /// cache that [`ReplayMode::Decoded`] replays execute directly.
    pub fn prepare(&mut self, ops: &[Operation]) -> Result<PreparedProgram> {
        self.stats.ops_in += ops.len();
        let geom = self.backend.geom();
        let gate_set = self.backend.gate_set();
        let items: Vec<Item> = ops.iter().cloned().map(Item::Op).collect();
        let items = self.apply_stages(0..self.front_len(), items, &geom, gate_set)?;
        let cache = match self.decode_model() {
            Some(model) => Some(Self::build_cache(model, &items, &geom, gate_set)?),
            None => None,
        };
        Ok(PreparedProgram { items, cache })
    }

    /// Decode + reconstruct every wire item once (the one periphery pass a
    /// [`ReplayMode::Decoded`] replay amortizes), recording the exact
    /// control-traffic cost a single wire replay of the stream would meter.
    fn build_cache(model: ModelKind, items: &[Item], geom: &Geometry, gate_set: GateSet) -> Result<DecodedCache> {
        let mut ops = Vec::with_capacity(items.len());
        let mut control_bits = 0u64;
        for item in items {
            match item {
                Item::Message(bits) => {
                    control_bits += bits.len() as u64;
                    let (class, msg) = encode::decode_with(model, bits, geom, gate_set)?;
                    ops.push(periphery::reconstruct_typed(class, &msg, geom)?);
                }
                Item::InitWrite { cols, value } => {
                    control_bits += init_message_bits(geom) as u64;
                    ops.push(Operation::Init { cols: cols.clone(), value: *value });
                }
                Item::Op(_) => bail!("wire pipeline staged an abstract operation past its encode stage"),
            }
        }
        Ok(DecodedCache { model, geom: *geom, ops, control_bits, messages: items.len() as u64 })
    }

    /// Stream a prepared program through the crossbar-side stages, by
    /// reference — no per-replay cloning. May be called any number of times;
    /// control traffic is metered on every run, exactly as a controller
    /// re-streaming the same encoded program would generate it.
    ///
    /// Under [`ReplayMode::Decoded`] (the default) a program prepared on a
    /// matching wire pipeline replays through its decode-once trusted op
    /// cache: the cached control cost is charged to [`PipelineStats`] and
    /// the trusted operations go to [`PimBackend::execute_trusted_batch`],
    /// skipping the per-replay periphery decode (and unlocking word-range
    /// parallelism). Any mismatch — wrong decode model, wrong geometry, no
    /// decode stage, no cache — falls back to the wire path, which fails
    /// exactly where an undecodable stream always failed.
    pub fn run_prepared(&mut self, prog: &PreparedProgram) -> Result<()> {
        let geom = self.backend.geom();
        if self.replay_mode == ReplayMode::Decoded {
            if let Some(cache) = &prog.cache {
                if self.decode_model() == Some(cache.model) && geom == cache.geom {
                    self.stats.control_bits += cache.control_bits;
                    self.stats.messages += cache.messages;
                    self.stats.ops_to_backend += cache.ops.len();
                    return self.backend.execute_trusted_batch(&cache.ops, self.replay_threads);
                }
            }
        }
        for item in &prog.items {
            self.consume_item(item.borrowed(), &geom)?;
        }
        Ok(())
    }

    /// Inject raw wire traffic at the crossbar boundary, skipping the
    /// controller-side stages: decode, reconstruct, execute. This models an
    /// untrusted or faulty controller (the fuzzing tests corrupt messages
    /// and assert the periphery either rejects them or reconstructs a
    /// physically valid operation).
    pub fn run_wire(&mut self, bits: &BitVec) -> Result<()> {
        ensure!(self.decoded, "pipeline has no periphery decode stage to receive wire traffic");
        let geom = self.backend.geom();
        self.consume_item(ItemRef::Message(bits), &geom)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ScalarCrossbar;
    use crate::crossbar::crossbar::Crossbar;
    use crate::isa::encode::message_bits;
    use crate::isa::operation::GateOp;

    fn geom() -> Geometry {
        Geometry::new(256, 8, 32).unwrap()
    }

    fn parallel_op(g: &Geometry) -> Operation {
        Operation::Gates((0..g.k).map(|p| GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 3))).collect())
    }

    #[test]
    fn stage_composition_validated() {
        let g = geom();
        let mut xb = Crossbar::new(g, GateSet::NotNor);
        // Decode without encode is rejected.
        assert!(ExecPipeline::new(vec![Stage::PeripheryDecode(ModelKind::Minimal)], &mut xb).is_err());
        // Encode without decode is rejected (the backend cannot execute bits).
        assert!(ExecPipeline::new(vec![Stage::Encode(ModelKind::Minimal)], &mut xb).is_err());
        // Mismatched encode/decode models are rejected.
        assert!(ExecPipeline::new(vec![Stage::Encode(ModelKind::Minimal), Stage::PeripheryDecode(ModelKind::Standard)], &mut xb).is_err());
        // Legalize after encode is rejected.
        assert!(ExecPipeline::new(
            vec![
                Stage::Encode(ModelKind::Minimal),
                Stage::PeripheryDecode(ModelKind::Minimal),
                Stage::Legalize { model: ModelKind::Minimal, cfg: LegalizeConfig::default() },
            ],
            &mut xb,
        )
        .is_err());
        // Verify between encode and decode is rejected (it checks abstract
        // operations, not wire traffic).
        assert!(ExecPipeline::new(
            vec![Stage::Encode(ModelKind::Minimal), Stage::Verify(ModelKind::Minimal), Stage::PeripheryDecode(ModelKind::Minimal)],
            &mut xb,
        )
        .is_err());
        // A verify-only pipeline is valid: direct execution plus static
        // checking.
        assert!(ExecPipeline::new(vec![Stage::Verify(ModelKind::Standard)], &mut xb).is_ok());
        // The three canonical shapes are valid.
        ExecPipeline::direct(&mut xb);
        ExecPipeline::wire(ModelKind::Minimal, &mut xb);
        ExecPipeline::full(ModelKind::Minimal, LegalizeConfig::default(), &mut xb);
    }

    #[test]
    fn wire_path_matches_direct_path_and_meters_control() {
        let g = geom();
        let op = parallel_op(&g);
        let init_op = Operation::init1(vec![g.col(0, 3), g.col(5, 3)]);

        let mut direct = Crossbar::new(g, GateSet::NotNor);
        direct.state.fill_random(77);
        let start = direct.state.clone();
        {
            let mut pipe = ExecPipeline::direct(&mut direct);
            pipe.run_ops(&[init_op.clone(), op.clone()]).unwrap();
            assert_eq!(pipe.stats().control_bits, 0, "direct path carries no wire traffic");
        }

        for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
            let mut xb = Crossbar::new(g, GateSet::NotNor);
            xb.state = start.clone();
            let mut pipe = ExecPipeline::wire(model, &mut xb);
            pipe.run_ops(&[init_op.clone(), op.clone()]).unwrap();
            let stats = pipe.stats();
            assert_eq!(stats.messages, 2);
            assert_eq!(stats.control_bits, (message_bits(model, &g) + init_message_bits(&g)) as u64);
            assert_eq!(pipe.metrics().control_bits, stats.control_bits);
            drop(pipe);
            assert_eq!(xb.state, direct.state, "{} wire path diverged", model.name());
        }
    }

    #[test]
    fn full_pipeline_legalizes_illegal_ops() {
        let g = geom();
        // Mixed distances (0, 1): standard-legal only after index grouping,
        // minimal-legal only after distance splitting.
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
            GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(3, 3)),
            GateOp::nor(g.col(5, 0), g.col(5, 1), g.col(5, 3)),
        ]);
        let mut direct = Crossbar::new(g, GateSet::NotNor);
        direct.state.fill_random(3);
        let start = direct.state.clone();
        ExecPipeline::direct(&mut direct).run_op(&op).unwrap();

        let mut xb = Crossbar::new(g, GateSet::NotNor);
        xb.state = start;
        let mut pipe = ExecPipeline::full(ModelKind::Minimal, LegalizeConfig::default(), &mut xb);
        pipe.run_op(&op).unwrap();
        let stats = pipe.stats();
        assert_eq!(stats.ops_in, 1);
        assert!(stats.ops_to_backend > 1, "minimal must split the mixed-distance cycle");
        assert_eq!(stats.messages as usize, stats.ops_to_backend);
        drop(pipe);
        assert_eq!(xb.state, direct.state);
        assert!(xb.metrics.cycles > direct.metrics.cycles, "legalization costs extra cycles");
    }

    #[test]
    fn malformed_init_on_wire_path_rejected_without_mutation() {
        let g = geom();
        let mut xb = Crossbar::new(g, GateSet::NotNor);
        xb.state.fill_random(5);
        let before = xb.state.clone();
        // Out-of-range write command: rejected before any cell is touched,
        // on the wire path exactly as on the direct path.
        let bad = Operation::Init { cols: vec![0, g.n + 7], value: true };
        assert!(ExecPipeline::wire(ModelKind::Minimal, &mut xb).run_op(&bad).is_err());
        assert!(ExecPipeline::direct(&mut xb).run_op(&bad).is_err());
        assert_eq!(xb.state, before, "rejected write must not touch any cell");
        // Empty write commands are rejected on both paths too.
        let empty = Operation::Init { cols: vec![], value: false };
        assert!(ExecPipeline::wire(ModelKind::Minimal, &mut xb).run_op(&empty).is_err());
        assert!(ExecPipeline::direct(&mut xb).run_op(&empty).is_err());
        assert_eq!(xb.state, before);
    }

    #[test]
    fn prepared_program_replays_and_meters_every_run() {
        let g = geom();
        let ops = vec![Operation::init1(vec![g.col(0, 3)]), parallel_op(&g)];
        let mut xb = Crossbar::new(g, GateSet::NotNor);
        let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
        let prepared = pipe.prepare(&ops).unwrap();
        assert_eq!(prepared.len(), 2);
        pipe.run_prepared(&prepared).unwrap();
        pipe.run_prepared(&prepared).unwrap();
        let stats = pipe.stats();
        assert_eq!(stats.messages, 4, "each replay streams every message again");
        assert_eq!(pipe.metrics().cycles, 4);
    }

    /// The replay fast path is invisible: a Decoded replay of a prepared
    /// program is bitwise- and metric-identical to a Wire replay, for both
    /// single- and multi-word-range execution.
    #[test]
    fn decoded_replay_matches_wire_replay() {
        let g = Geometry::new(256, 8, 130).unwrap(); // 3 words/col: real word ranges
        let ops = vec![
            Operation::init1(vec![g.col(0, 3), g.col(2, 3)]),
            parallel_op(&g),
            Operation::init1(vec![g.col(1, 2)]),
            parallel_op(&g),
        ];
        let mut scratch = Crossbar::new(g, GateSet::NotNor);
        let prepared = ExecPipeline::wire(ModelKind::Minimal, &mut scratch).prepare(&ops).unwrap();
        assert!(prepared.is_decoded());

        let mut start = Crossbar::new(g, GateSet::NotNor);
        start.state.fill_random(41);
        let mut outcomes = Vec::new();
        for (mode, threads) in [(ReplayMode::Wire, 1), (ReplayMode::Decoded, 1), (ReplayMode::Decoded, 3)] {
            let mut xb = start.clone();
            let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
            pipe.set_replay_mode(mode);
            pipe.set_replay_threads(threads);
            pipe.run_prepared(&prepared).unwrap();
            pipe.run_prepared(&prepared).unwrap();
            let stats = pipe.stats();
            let metrics = pipe.metrics();
            drop(pipe);
            outcomes.push((xb.state, stats.ops_to_backend, stats.control_bits, stats.messages, metrics));
        }
        for o in &outcomes[1..] {
            assert_eq!(o.0, outcomes[0].0, "replay modes diverged in state");
            assert_eq!(
                (o.1, o.2, o.3, o.4),
                (outcomes[0].1, outcomes[0].2, outcomes[0].3, outcomes[0].4),
                "replay modes diverged in metering"
            );
        }
    }

    /// The replay metering contract (see [`PipelineStats`]): `ops_in` is
    /// charged once at prepare, and N replays grow `ops_to_backend`,
    /// `control_bits`, `messages` and the backend counters by exactly N ×
    /// the single-replay deltas — identically in both replay modes.
    #[test]
    fn n_replays_meter_exactly_n_times_the_wire_deltas() {
        let g = geom();
        let ops = vec![Operation::init1(vec![g.col(0, 3)]), parallel_op(&g), parallel_op(&g)];
        for mode in [ReplayMode::Decoded, ReplayMode::Wire] {
            let mut xb = Crossbar::new(g, GateSet::NotNor);
            let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
            pipe.set_replay_mode(mode);
            let prepared = pipe.prepare(&ops).unwrap();
            let after_prepare = pipe.stats();
            assert_eq!(after_prepare.ops_in, 3);
            assert_eq!(after_prepare.messages, 0, "prepare must not meter the wire");
            assert_eq!(pipe.metrics().cycles, 0, "prepare must not execute");
            pipe.run_prepared(&prepared).unwrap();
            let one = pipe.stats();
            let one_metrics = pipe.metrics();
            assert!(one.control_bits > 0 && one.messages == 3);
            for _ in 0..4 {
                pipe.run_prepared(&prepared).unwrap();
            }
            let five = pipe.stats();
            assert_eq!(five.ops_in, 3, "replays never re-charge ops_in");
            assert_eq!(five.ops_to_backend, 5 * one.ops_to_backend);
            assert_eq!(five.control_bits, 5 * one.control_bits);
            assert_eq!(five.messages, 5 * one.messages);
            assert_eq!(pipe.metrics().cycles, 5 * one_metrics.cycles);
        }
    }

    #[test]
    fn prepared_program_rejected_on_mismatched_pipeline() {
        let g = geom();
        let ops = vec![parallel_op(&g)];
        let mut xb = Crossbar::new(g, GateSet::NotNor);
        let prepared = ExecPipeline::wire(ModelKind::Minimal, &mut xb).prepare(&ops).unwrap();
        // Running minimal-encoded traffic through a standard decoder fails
        // at the length check instead of corrupting state.
        assert!(ExecPipeline::wire(ModelKind::Standard, &mut xb).run_prepared(&prepared).is_err());
        // Running wire traffic into a direct pipeline fails at the backend
        // boundary (undecoded items are rejected, not executed).
        assert!(ExecPipeline::direct(&mut xb).run_prepared(&prepared).is_err());
    }

    /// The acceptance case for the verify stage: an aperiodic minimal-model
    /// cycle that the encoder happily accepts (the range-generator fields
    /// only capture the first gap), but that the periphery would expand to
    /// *different* gates — silent mis-execution. The wire path must reject
    /// it before any backend state changes.
    #[test]
    fn verify_stage_rejects_silent_misexecution_before_the_wire() {
        let g = geom();
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
            GateOp::nor(g.col(1, 0), g.col(1, 1), g.col(1, 3)),
            GateOp::nor(g.col(4, 0), g.col(4, 1), g.col(4, 3)),
        ]);
        // The op is physically valid and the encoder accepts it...
        op.validate(&g, GateSet::NotNor).unwrap();
        assert!(encode::encode(ModelKind::Minimal, &op, &g).is_ok());
        // ...but the decoded message executes five gates, not three.
        let msg = encode::to_message(ModelKind::Minimal, &op, &g).unwrap();
        let rec = periphery::reconstruct(&msg, &g).unwrap();
        assert_ne!(rec.normalized(), op.normalized());

        let mut xb = Crossbar::new(g, GateSet::NotNor);
        xb.state.fill_random(9);
        let before = xb.state.clone();
        let mut pipe = ExecPipeline::wire(ModelKind::Minimal, &mut xb);
        assert!(pipe.run_op(&op).is_err(), "verify stage must reject the aperiodic cycle");
        assert!(pipe.prepare(std::slice::from_ref(&op)).is_err(), "prepare runs the same verify stage");
        assert_eq!(pipe.metrics().cycles, 0, "nothing may reach the backend");
        assert_eq!(pipe.stats().messages, 0, "nothing may reach the wire");
        drop(pipe);
        assert_eq!(xb.state, before, "rejected cycle must not touch any cell");
    }

    #[test]
    fn verify_only_pipeline_checks_before_direct_execution() {
        let g = geom();
        let mut xb = Crossbar::new(g, GateSet::NotNor);
        let mut pipe = ExecPipeline::new(vec![Stage::Verify(ModelKind::Standard)], &mut xb).unwrap();
        pipe.run_op(&parallel_op(&g)).unwrap();
        // Mixed directions: a V012 error under the standard model.
        let mixed = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(1, 3)),
            GateOp::nor(g.col(5, 0), g.col(5, 1), g.col(4, 3)),
        ]);
        assert!(pipe.run_op(&mixed).is_err());
        assert_eq!(pipe.metrics().cycles, 1);
    }

    #[test]
    fn pipeline_works_across_backends() {
        let g = geom();
        let ops = vec![Operation::init1(vec![g.col(1, 5)]), parallel_op(&g)];
        let mut bitpacked = Crossbar::new(g, GateSet::NotNor);
        bitpacked.state.fill_random(21);
        let start = bitpacked.state.clone();
        let mut scalar = ScalarCrossbar::new(g, GateSet::NotNor);
        scalar.load_state(&start).unwrap();

        ExecPipeline::wire(ModelKind::Minimal, &mut bitpacked).run_ops(&ops).unwrap();
        ExecPipeline::wire(ModelKind::Minimal, &mut scalar).run_ops(&ops).unwrap();
        assert_eq!(bitpacked.state_bits().unwrap(), scalar.state_bits().unwrap());
    }
}
