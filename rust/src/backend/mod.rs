//! The execution seam of the whole system.
//!
//! Every way of physically realizing a partition-operation stream — the
//! bit-packed word-parallel simulator ([`crate::crossbar::Crossbar`]), the
//! naive scalar reference oracle ([`ScalarCrossbar`]), the AOT-compiled
//! XLA/Pallas step kernel ([`crate::runtime::XlaCrossbar`]), and any future
//! backend (GPU, sharded banks) — implements the one [`PimBackend`] trait.
//! Programs never talk to a backend directly: they flow through an
//! [`ExecPipeline`], an explicit composition of control stages
//! (legalize → encode → periphery-decode → backend) that meters latency,
//! gates, and control traffic uniformly at every stage boundary.
//!
//! See `DESIGN.md` §Backends for the architecture rationale.

pub mod pipeline;
pub mod scalar;

pub use pipeline::{ExecPipeline, PipelineStats, PreparedProgram, ReplayMode, Stage};
pub use scalar::ScalarCrossbar;

use crate::crossbar::crossbar::Metrics;
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::operation::Operation;
use anyhow::Result;

/// Shared [`PimBackend::load_state`] shape validation: every backend must
/// reject a state image whose dimensions disagree with its geometry, with
/// one canonical message.
pub fn check_state_shape(geom: &Geometry, m: &BitMatrix) -> Result<()> {
    anyhow::ensure!(
        m.rows() == geom.rows && m.cols() == geom.n,
        "state shape {}x{} does not match geometry {}x{}",
        m.rows(),
        m.cols(),
        geom.rows,
        geom.n
    );
    Ok(())
}

/// A device that executes abstract partition operations.
///
/// The surface is deliberately minimal: state in, one operation per
/// simulated cycle, state out, plus the architectural counters. Everything
/// model-specific (wire formats, legality, periphery decoding) lives in the
/// [`ExecPipeline`] stages in front of the backend, so a backend never needs
/// to know which of the paper's designs is driving it.
pub trait PimBackend {
    /// Human-readable backend identifier (for reports and error messages).
    fn name(&self) -> &'static str;

    /// The crossbar geometry this backend simulates.
    fn geom(&self) -> Geometry;

    /// The stateful-logic gate set this backend supports.
    fn gate_set(&self) -> GateSet;

    /// Overwrite the full crossbar state.
    fn load_state(&mut self, m: &BitMatrix) -> Result<()>;

    /// Snapshot the full crossbar state.
    fn state_bits(&self) -> Result<BitMatrix>;

    /// Execute one abstract operation (one simulated cycle), validating the
    /// physical constraints (column ranges, section disjointness, gate set).
    fn execute(&mut self, op: &Operation) -> Result<()>;

    /// Execute a cycle that is already known physically valid — the
    /// periphery decode stage uses this after message reconstruction (which
    /// guarantees disjoint sections and alias-free gates by construction),
    /// so the hot message path does not validate twice. Backends without a
    /// cheaper trusted path fall back to [`PimBackend::execute`].
    fn execute_trusted(&mut self, op: &Operation) -> Result<()> {
        self.execute(op)
    }

    /// Execute a sequence of operations. This provided method is the single
    /// op-stream loop in the crate; per-backend copies of it are exactly the
    /// duplication the trait exists to remove.
    fn execute_ops(&mut self, ops: &[Operation]) -> Result<()> {
        for op in ops {
            self.execute(op)?;
        }
        Ok(())
    }

    /// Execute a whole trusted operation stream (a decoded replay batch),
    /// with permission to spread row-parallel work over up to `threads`
    /// word-range executors. Gate cycles are trusted (periphery-reconstructed
    /// — see [`PimBackend::execute_trusted`]); write commands still take the
    /// validating path, exactly as they do on the wire.
    ///
    /// The default implementation is the serial wire-equivalent loop; the
    /// bit-packed crossbar overrides it with word-range-parallel execution
    /// (DESIGN.md §Replay fast path). Implementations must preserve exact
    /// metric semantics — `switch_events` and the per-row tracked variants
    /// must match the serial path bit for bit.
    fn execute_trusted_batch(&mut self, ops: &[Operation], threads: usize) -> Result<()> {
        let _ = threads;
        for op in ops {
            match op {
                Operation::Init { .. } => self.execute(op)?,
                Operation::Gates(_) => self.execute_trusted(op)?,
            }
        }
        Ok(())
    }

    /// Architectural counters accumulated by this backend (cycles, gates,
    /// switching events). Control traffic is metered by the pipeline, not
    /// the backend — see [`ExecPipeline::metrics`] for the merged view.
    fn metrics(&self) -> Metrics;

    /// Reset the counters (state is preserved).
    fn reset_metrics(&mut self);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::crossbar::Crossbar;
    use crate::isa::operation::GateOp;

    /// The two CPU backends behave identically through the same trait
    /// object — the minimal differential smoke test (the full property
    /// lives in `tests/proptests.rs`).
    #[test]
    fn trait_object_backends_agree() {
        let geom = Geometry::new(128, 4, 16).unwrap();
        let ops = vec![
            Operation::init1(vec![2, 40, 70]),
            Operation::Gates(vec![GateOp::nor(0, 1, 2), GateOp::nor(38, 39, 40)]),
            Operation::serial(GateOp::not(2, 70)),
        ];
        let mut bitpacked = Crossbar::new(geom, GateSet::NotNor);
        bitpacked.state.fill_random(9);
        let init = bitpacked.state.clone();
        let mut scalar = ScalarCrossbar::new(geom, GateSet::NotNor);

        let mut states = Vec::new();
        for backend in [&mut bitpacked as &mut dyn PimBackend, &mut scalar as &mut dyn PimBackend] {
            backend.load_state(&init).unwrap();
            backend.execute_ops(&ops).unwrap();
            let m = backend.metrics();
            assert_eq!(m.cycles, 3, "{}", backend.name());
            assert_eq!(m.gate_events, 3, "{}", backend.name());
            states.push(backend.state_bits().unwrap());
        }
        assert_eq!(states[0], states[1]);
        assert_eq!(bitpacked.metrics().switch_events, scalar.metrics().switch_events);
    }

    #[test]
    fn load_state_rejects_shape_mismatch() {
        let geom = Geometry::new(128, 4, 16).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let wrong = BitMatrix::new(8, 128);
        assert!(PimBackend::load_state(&mut xb, &wrong).is_err());
        let mut sc = ScalarCrossbar::new(geom, GateSet::NotNor);
        assert!(sc.load_state(&wrong).is_err());
    }
}
