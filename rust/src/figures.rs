//! Structured generators for every table/figure of the paper's evaluation —
//! shared by the CLI (`repro report` / `repro figure6`), the benches and the
//! examples. See DESIGN.md §5 for the experiment index.

use crate::algorithms::mult_serial::build_serial_multiplier;
use crate::algorithms::multpim::{build_multpim, MultPimVariant};
use crate::algorithms::program::ProgramStats;
use crate::algorithms::sort::{build_sorter_partitioned, build_sorter_serial};
use crate::analysis::counts::operation_count;
use crate::coordinator::worker::{compile_workload, workload_geometry, WorkloadKind};
use crate::crossbar::geometry::Geometry;
use crate::isa::encode::message_bits;
use crate::isa::models::ModelKind;
use crate::periphery::area::{naive_unlimited_area, periphery_area, transistor_area_overhead, PeripheryArea};
use anyhow::Result;

/// One row of Figure 6 (latency / control / area / energy for 32-bit
/// multiplication under one model).
#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub model: ModelKind,
    pub stats: ProgramStats,
    /// Figure 6(a): serial-baseline cycles / this model's cycles.
    pub speedup_vs_serial: f64,
    /// Figure 6(b): per-cycle gate-message length in bits.
    pub message_bits: usize,
    /// Figure 6(b): message length relative to the 30-bit baseline.
    pub control_overhead: f64,
    /// Figure 6(c): memristor footprint relative to the serial baseline.
    pub area_ratio: f64,
    /// Section 5.4: total gate count relative to the serial baseline.
    pub energy_ratio: f64,
}

/// Regenerate Figure 6 at paper scale (n=1024, k=32, 32-bit multiplication).
pub fn figure6() -> Result<Vec<Fig6Row>> {
    let mut rows = Vec::new();
    let base_geom = workload_geometry(WorkloadKind::Mul32, ModelKind::Baseline, 1)?;
    let (base_prog, _) = compile_workload(WorkloadKind::Mul32, ModelKind::Baseline, base_geom)?;
    let base = base_prog.stats();
    for model in [ModelKind::Baseline, ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
        let geom = workload_geometry(WorkloadKind::Mul32, model, 1)?;
        let (prog, _) = compile_workload(WorkloadKind::Mul32, model, geom)?;
        let stats = prog.stats();
        // Control overhead compares gate-message lengths on the paper's
        // n=1024, k=32 crossbar (the baseline row uses the 30-bit format).
        let paper_geom = Geometry::paper(1)?;
        let bits = message_bits(model, &paper_geom);
        rows.push(Fig6Row {
            model,
            stats,
            speedup_vs_serial: base.cycles as f64 / stats.cycles as f64,
            message_bits: bits,
            control_overhead: bits as f64 / message_bits(ModelKind::Baseline, &paper_geom) as f64,
            area_ratio: stats.footprint_cols as f64 / base.footprint_cols as f64,
            energy_ratio: stats.gates as f64 / base.gates as f64,
        });
    }
    Ok(rows)
}

/// Sections 2.3 / 3.3 / 4.3: message formats vs information-theoretic lower
/// bounds (experiments E2–E5).
#[derive(Debug, Clone)]
pub struct ControlRow {
    pub model: ModelKind,
    pub format_bits: usize,
    pub lower_bound_bits: usize,
    pub operation_count_decimal: String,
}

pub fn control_table(geom: &Geometry) -> Vec<ControlRow> {
    ModelKind::ALL
        .iter()
        .map(|&model| {
            let c = operation_count(model, geom);
            ControlRow {
                model,
                format_bits: message_bits(model, geom),
                lower_bound_bits: c.lower_bound_bits,
                operation_count_decimal: c.count.to_string(),
            }
        })
        .collect()
}

/// Experiment E12: periphery gate counts per design plus the naive stack.
#[derive(Debug, Clone)]
pub struct PeripheryRow {
    pub name: &'static str,
    pub area: PeripheryArea,
}

pub fn periphery_table(geom: &Geometry) -> Vec<PeripheryRow> {
    let mut rows: Vec<PeripheryRow> = ModelKind::ALL
        .iter()
        .map(|&m| PeripheryRow { name: m.name(), area: periphery_area(m, geom) })
        .collect();
    rows.push(PeripheryRow { name: "naive-stack (Fig 3b)", area: naive_unlimited_area(geom) });
    rows
}

/// The ≈3% isolation-transistor overhead [8].
pub fn transistor_overhead(geom: &Geometry) -> f64 {
    transistor_area_overhead(geom)
}

/// Experiment E10: sorting speedup (paper intro: 14× with 16 partitions).
#[derive(Debug, Clone)]
pub struct SortRow {
    pub elems: usize,
    pub w_bits: usize,
    pub serial_cycles: usize,
    pub partitioned_cycles: usize,
    pub speedup: f64,
}

pub fn sort_table(w_bits: usize) -> Result<Vec<SortRow>> {
    let mut rows = Vec::new();
    for k in [4usize, 8, 16] {
        let par = build_sorter_partitioned(Geometry::new((32 * k).next_power_of_two(), k, 1)?, w_bits)?;
        let ser = build_sorter_serial(Geometry::new(1024, 1, 1)?, k, w_bits)?;
        let (p, s) = (par.program.stats().cycles, ser.program.stats().cycles);
        rows.push(SortRow { elems: k, w_bits, serial_cycles: s, partitioned_cycles: p, speedup: s as f64 / p as f64 });
    }
    Ok(rows)
}

/// Ablation: the three broadcast strategies inside MultPIM (log-tree
/// double-NOT vs log-tree parity vs what a chain would cost).
#[derive(Debug, Clone)]
pub struct BroadcastRow {
    pub name: &'static str,
    pub cycles: usize,
    pub gates: usize,
}

pub fn broadcast_ablation(geom: Geometry) -> Result<Vec<BroadcastRow>> {
    let plain = build_multpim(geom, MultPimVariant::Plain)?.program.stats();
    let fast = build_multpim(geom, MultPimVariant::Fast)?.program.stats();
    Ok(vec![
        BroadcastRow { name: "double-NOT tree (minimal-legal)", cycles: plain.cycles, gates: plain.gates },
        BroadcastRow { name: "parity tree (standard-legal)", cycles: fast.cycles, gates: fast.gates },
    ])
}

/// The paper's central trade-off swept across partition counts: more
/// partitions buy speedup but inflate the unlimited control message, while
/// minimal stays near the baseline — the scaling argument behind Sections
/// 2.3-4.3.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub k: usize,
    pub speedup: f64,
    pub bits_unlimited: usize,
    pub bits_standard: usize,
    pub bits_minimal: usize,
    pub transistor_overhead: f64,
}

pub fn partition_sweep() -> Result<Vec<SweepRow>> {
    let ser = build_serial_multiplier(Geometry::new(1024, 1, 1)?, 32)?.program.stats().cycles;
    let mut rows = Vec::new();
    for k in [4usize, 8, 16, 32] {
        // k partitions multiply k-bit operands in MultPIM's layout; scale the
        // serial baseline to the same width for a like-for-like speedup.
        let geom = Geometry::new(1024, k, 1)?;
        let par = build_multpim(geom, MultPimVariant::Plain)?.program.stats().cycles;
        let ser_k = build_serial_multiplier(Geometry::new(1024, 1, 1)?, k.max(4))?.program.stats().cycles;
        let _ = ser;
        rows.push(SweepRow {
            k,
            speedup: ser_k as f64 / par as f64,
            bits_unlimited: message_bits(ModelKind::Unlimited, &geom),
            bits_standard: message_bits(ModelKind::Standard, &geom),
            bits_minimal: message_bits(ModelKind::Minimal, &geom),
            transistor_overhead: transistor_area_overhead(&geom),
        });
    }
    Ok(rows)
}

/// Multiplication scaling across widths (supporting data for Fig 6(a)).
pub fn mult_scaling() -> Result<Vec<(usize, usize, usize, f64)>> {
    let mut rows = Vec::new();
    for n in [4usize, 8, 16, 32] {
        let par_geom = Geometry::new((32 * n).next_power_of_two(), n, 1)?;
        let par = build_multpim(par_geom, MultPimVariant::Plain)?.program.stats().cycles;
        let ser = build_serial_multiplier(Geometry::new(1024, 1, 1)?, n)?.program.stats().cycles;
        rows.push((n, ser, par, ser as f64 / par as f64));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Figure 6 shape checks: the orderings and rough factors the paper
    /// reports must hold (exact values differ — our simulator, not theirs).
    #[test]
    fn figure6_shape() {
        let rows = figure6().unwrap();
        let get = |m: ModelKind| rows.iter().find(|r| r.model == m).unwrap();
        let (unl, std_, min) = (get(ModelKind::Unlimited), get(ModelKind::Standard), get(ModelKind::Minimal));
        // (a) latency: all partitioned models 5-15x over serial; unl >= std >= min speedups.
        for r in [unl, std_, min] {
            assert!(r.speedup_vs_serial > 5.0 && r.speedup_vs_serial < 20.0, "{}: {}", r.model.name(), r.speedup_vs_serial);
        }
        assert!(unl.speedup_vs_serial >= std_.speedup_vs_serial);
        assert!(std_.speedup_vs_serial >= min.speedup_vs_serial);
        // (b) control: 20.2x / 2.6x / 1.2x.
        assert_eq!(unl.message_bits, 607);
        assert_eq!(std_.message_bits, 79);
        assert_eq!(min.message_bits, 36);
        // (c) area: parallel approaches cost more memristors than serial.
        for r in [unl, std_, min] {
            assert!(r.area_ratio > 1.0, "{}: {}", r.model.name(), r.area_ratio);
        }
        // energy: more gates than serial (paper: 2.1x).
        for r in [unl, std_, min] {
            assert!(r.energy_ratio > 1.0, "{}: {}", r.model.name(), r.energy_ratio);
        }
    }

    #[test]
    fn partition_sweep_tradeoff() {
        let rows = partition_sweep().unwrap();
        // Speedup grows with k; unlimited control grows fast; minimal stays
        // within 2x of the 30-bit baseline everywhere.
        assert!(rows.windows(2).all(|w| w[1].speedup > w[0].speedup));
        assert!(rows.windows(2).all(|w| w[1].bits_unlimited > w[0].bits_unlimited));
        for r in &rows {
            assert!(r.bits_minimal <= 60, "k={}: minimal format {} bits", r.k, r.bits_minimal);
            assert!(r.transistor_overhead < 0.04);
        }
    }

    #[test]
    fn sort_speedup_grows_with_k() {
        let rows = sort_table(6).unwrap();
        assert!(rows.windows(2).all(|w| w[1].speedup > w[0].speedup));
        let k16 = rows.iter().find(|r| r.elems == 16).unwrap();
        assert!(k16.speedup > 2.0, "16-element speedup {}", k16.speedup);
    }
}
