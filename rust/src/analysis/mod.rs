//! Combinatorial analysis of the operation sets: counting supported
//! operations to lower-bound the control-message length of any
//! implementation (Sections 2.3, 3.3, 4.3).

pub mod bigint;
pub mod counts;

pub use counts::{lower_bound_bits, operation_count, OperationCount};
