//! A tiny arbitrary-precision unsigned integer — just enough to count
//! operation sets like `[C(n/k, 2) · (n/k − 2)]^k ≈ 2^443` exactly.

use std::cmp::Ordering;
use std::fmt;

/// Arbitrary-precision unsigned integer, little-endian 64-bit limbs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BigUint {
    /// Invariant: no trailing zero limbs (zero is the empty vec).
    limbs: Vec<u64>,
}

impl BigUint {
    pub fn zero() -> Self {
        Self { limbs: vec![] }
    }

    pub fn from_u128(v: u128) -> Self {
        let lo = v as u64;
        let hi = (v >> 64) as u64;
        let mut b = Self { limbs: vec![lo, hi] };
        b.trim();
        b
    }

    pub fn from_u64(v: u64) -> Self {
        Self::from_u128(v as u128)
    }

    fn trim(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    /// Number of bits in the binary representation (0 for zero).
    pub fn bit_length(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => 64 * (self.limbs.len() - 1) + (64 - top.leading_zeros() as usize),
        }
    }

    /// `self += other`.
    pub fn add_assign(&mut self, other: &BigUint) {
        let n = self.limbs.len().max(other.limbs.len());
        self.limbs.resize(n, 0);
        let mut carry = 0u64;
        for i in 0..n {
            let b = *other.limbs.get(i).unwrap_or(&0);
            let (s1, c1) = self.limbs[i].overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            self.limbs[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry > 0 {
            self.limbs.push(carry);
        }
    }

    /// `self *= m` for a small multiplier.
    pub fn mul_u64(&mut self, m: u64) {
        if m == 0 {
            self.limbs.clear();
            return;
        }
        let mut carry = 0u128;
        for limb in self.limbs.iter_mut() {
            let prod = *limb as u128 * m as u128 + carry;
            *limb = prod as u64;
            carry = prod >> 64;
        }
        while carry > 0 {
            self.limbs.push(carry as u64);
            carry >>= 64;
        }
    }

    /// `base^exp` for a u64 base.
    pub fn pow_u64(base: u64, exp: u32) -> BigUint {
        let mut acc = BigUint::from_u64(1);
        for _ in 0..exp {
            acc.mul_u64(base);
        }
        acc
    }

    pub fn cmp_big(&self, other: &BigUint) -> Ordering {
        match self.limbs.len().cmp(&other.limbs.len()) {
            Ordering::Equal => {
                for i in (0..self.limbs.len()).rev() {
                    match self.limbs[i].cmp(&other.limbs[i]) {
                        Ordering::Equal => continue,
                        ord => return ord,
                    }
                }
                Ordering::Equal
            }
            ord => ord,
        }
    }
}

impl fmt::Display for BigUint {
    /// Decimal rendering (repeated division by 10^19) — slow but only used
    /// in reports.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_zero() {
            return write!(f, "0");
        }
        let mut limbs = self.limbs.clone();
        let mut chunks: Vec<u64> = Vec::new();
        const BASE: u64 = 10_000_000_000_000_000_000; // 10^19
        while !limbs.is_empty() {
            let mut rem = 0u128;
            for limb in limbs.iter_mut().rev() {
                let cur = (rem << 64) | *limb as u128;
                *limb = (cur / BASE as u128) as u64;
                rem = cur % BASE as u128;
            }
            while limbs.last() == Some(&0) {
                limbs.pop();
            }
            chunks.push(rem as u64);
        }
        write!(f, "{}", chunks.last().unwrap())?;
        for c in chunks.iter().rev().skip(1) {
            write!(f, "{c:019}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_lengths() {
        assert_eq!(BigUint::zero().bit_length(), 0);
        assert_eq!(BigUint::from_u64(1).bit_length(), 1);
        assert_eq!(BigUint::from_u64(255).bit_length(), 8);
        assert_eq!(BigUint::from_u64(256).bit_length(), 9);
        assert_eq!(BigUint::from_u128(1u128 << 100).bit_length(), 101);
    }

    #[test]
    fn pow_matches_shift() {
        // 2^443 has bit length 444.
        assert_eq!(BigUint::pow_u64(2, 443).bit_length(), 444);
    }

    #[test]
    fn display_decimal() {
        assert_eq!(BigUint::from_u64(0).to_string(), "0");
        assert_eq!(BigUint::from_u64(12345).to_string(), "12345");
        assert_eq!(BigUint::from_u128(123456789012345678901234567890u128).to_string(), "123456789012345678901234567890");
        let mut v = BigUint::from_u64(1);
        v.mul_u64(u64::MAX);
        v.mul_u64(u64::MAX);
        // (2^64-1)^2 = 2^128 - 2^65 + 1
        assert_eq!(v.to_string(), "340282366920938463426481119284349108225");
    }

    #[test]
    fn add_with_carry() {
        let mut a = BigUint::from_u64(u64::MAX);
        a.add_assign(&BigUint::from_u64(1));
        assert_eq!(a, BigUint::from_u128(1u128 << 64));
    }
}
