//! Counting supported operations per design — the message-length lower
//! bounds of Sections 2.3, 3.3 and 4.3.

use crate::analysis::bigint::BigUint;
use crate::crossbar::geometry::Geometry;
use crate::isa::models::ModelKind;

/// The operation count of a design and the bit lower bound it implies.
#[derive(Debug, Clone)]
pub struct OperationCount {
    pub model: ModelKind,
    pub count: BigUint,
    /// `ceil(log2(count))` — any implementation needs at least this many
    /// message bits.
    pub lower_bound_bits: usize,
}

/// `C(n, 2) = n(n-1)/2` as u64 (fits easily for crossbar sizes).
fn choose2(n: u64) -> u64 {
    n * (n - 1) / 2
}

/// `C(n, r)` as u128 for the standard-model enable-pattern count.
fn choose(n: u64, r: u64) -> u128 {
    if r > n {
        return 0;
    }
    let r = r.min(n - r);
    let mut num = 1u128;
    let mut den = 1u128;
    for i in 0..r {
        num *= (n - i) as u128;
        den *= (i + 1) as u128;
    }
    num / den
}

/// Count the operations supported by `model` (the paper's lower-bound
/// counting — deliberately an *under*count for unlimited/standard since
/// semi-parallel variants are omitted, "valid as we seek a lower-bound").
pub fn operation_count(model: ModelKind, geom: &Geometry) -> OperationCount {
    let n = geom.n as u64;
    let k = geom.k as u64;
    let m = (geom.n / geom.k) as u64;
    let count = match model {
        // All serial gates: C(n,2) choices of {InA, InB} times (n-2) outputs.
        ModelKind::Baseline => BigUint::from_u128(choose2(n) as u128 * (n - 2) as u128),
        // Serial + parallel (semi-parallel omitted, Section 2.3):
        //   C(n,2)(n-2)  +  [C(m,2)(m-2)]^k.
        ModelKind::Unlimited => {
            let mut parallel = BigUint::from_u64(1);
            let per_partition = choose2(m) * (m - 2);
            for _ in 0..k {
                parallel.mul_u64(per_partition);
            }
            parallel.add_assign(&BigUint::from_u128(choose2(n) as u128 * (n - 2) as u128));
            parallel
        }
        // Section 3.3: 2 · Σ_{q=1}^{k} C(k-1, q-1) · C(m,2) · (m-2)
        // (direction × enable patterns × shared index choices).
        ModelKind::Standard => {
            let mut sum = 0u128;
            for q in 1..=k {
                sum += choose(k - 1, q - 1);
            }
            let per = choose2(m) as u128 * (m - 2) as u128;
            BigUint::from_u128(2 * sum * per)
        }
        // Section 4.3: all non-input-split serial operations are supported:
        // k partitions × m(m-1) ordered input pairs × (n-2) outputs.
        ModelKind::Minimal => BigUint::from_u128(k as u128 * (m as u128 * (m - 1) as u128) * (n - 2) as u128),
    };
    // ceil(log2(count)): bit_length(count - 1)... for lower bounds the paper
    // uses ceil(log2(count)), which equals bit_length(count) when count is
    // not a power of two (true for all of these).
    let lower_bound_bits = count.bit_length();
    OperationCount { model, count, lower_bound_bits }
}

/// Convenience: just the bit lower bound.
pub fn lower_bound_bits(model: ModelKind, geom: &Geometry) -> usize {
    operation_count(model, geom).lower_bound_bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::message_bits;

    fn paper() -> Geometry {
        Geometry::paper(64).unwrap()
    }

    /// Section 2.3: "over 2^443 different operations, thus ... at least
    /// 443 bits" (experiment E3).
    #[test]
    fn unlimited_bound_443() {
        let c = operation_count(ModelKind::Unlimited, &paper());
        // count > 2^443  <=>  bit_length >= 444
        assert_eq!(c.lower_bound_bits, 444);
        let two_443 = BigUint::pow_u64(2, 443);
        assert_eq!(c.count.cmp_big(&two_443), std::cmp::Ordering::Greater);
    }

    /// Section 3.3: "a 46 bit lower-bound" (experiment E4).
    #[test]
    fn standard_bound_46() {
        let c = operation_count(ModelKind::Standard, &paper());
        assert_eq!(c.lower_bound_bits, 46);
    }

    /// Section 4.3: "a lower bound of at least 25 bits" (experiment E5).
    #[test]
    fn minimal_bound_25() {
        let c = operation_count(ModelKind::Minimal, &paper());
        assert_eq!(c.lower_bound_bits, 25);
    }

    /// Baseline sanity: C(1024,2)·1022 ≈ 2^28.996 → the 30-bit format is
    /// within one bit of the information-theoretic bound.
    #[test]
    fn baseline_bound_matches_format() {
        let g = paper();
        let c = operation_count(ModelKind::Baseline, &g);
        assert!(c.lower_bound_bits <= message_bits(ModelKind::Baseline, &g));
        assert_eq!(c.lower_bound_bits, 29);
    }

    /// The paper's consistency claims: every format is at least as long as
    /// its lower bound, and "not very far" from it.
    #[test]
    fn formats_dominate_bounds() {
        let g = paper();
        for m in ModelKind::ALL {
            let bound = lower_bound_bits(m, &g);
            let fmt = message_bits(m, &g);
            assert!(fmt >= bound, "{}: format {fmt} < bound {bound}", m.name());
        }
        // 607 vs 443+1, 79 vs 46, 36 vs 25 — same ballpark as the paper.
        assert_eq!(message_bits(ModelKind::Unlimited, &g), 607);
        assert_eq!(message_bits(ModelKind::Standard, &g), 79);
        assert_eq!(message_bits(ModelKind::Minimal, &g), 36);
    }
}
