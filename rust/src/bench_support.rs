//! A small criterion-style measurement harness (criterion itself is not in
//! the offline vendor set — see DESIGN.md §Substitutions).
//!
//! Auto-calibrates iteration counts to ~200ms per benchmark, reports
//! mean / stddev / throughput over multiple samples.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub stddev: Duration,
    pub samples: usize,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }
}

fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Measure `f`, auto-calibrating so each of the `samples` runs takes
/// roughly `target` wall time. Prints a criterion-like line.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    let target = Duration::from_millis(40);
    let samples = 5usize;
    // Calibrate.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(20));
    let iters = (target.as_nanos() / once.as_nanos()).clamp(1, 1_000_000) as u64;

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        times.push(t.elapsed().as_secs_f64() / iters as f64);
    }
    let mean = times.iter().sum::<f64>() / samples as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / samples as f64;
    let res = BenchResult {
        name: name.to_string(),
        mean: Duration::from_secs_f64(mean),
        stddev: Duration::from_secs_f64(var.sqrt()),
        samples,
        iters_per_sample: iters,
    };
    println!(
        "bench {:<44} {:>12} ± {:<10} ({} samples x {} iters)",
        res.name,
        fmt_duration(res.mean),
        fmt_duration(res.stddev),
        res.samples,
        res.iters_per_sample
    );
    res
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Report a throughput number derived from a bench result.
pub fn throughput(res: &BenchResult, units: f64, unit_name: &str) {
    let per_sec = units / res.mean.as_secs_f64();
    let formatted = if per_sec >= 1e9 {
        format!("{:.2} G{unit_name}/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M{unit_name}/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} k{unit_name}/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} {unit_name}/s")
    };
    println!("      -> {formatted}");
}
