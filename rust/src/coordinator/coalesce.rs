//! Cross-job chunk coalescing: the dispatcher-side packing policy that
//! turns per-job partial chunks into full shared row-batches.
//!
//! The crossbar is row-parallel by construction — a program replay costs
//! the same whether 1 or 64 rows hold operands — so shipping a 1-element
//! job alone wastes almost the whole bank. The [`Coalescer`] holds every
//! pending segment in arrival order and releases *batches*:
//!
//! * **Greedy front-anchored first-fit.** The oldest pending segment always
//!   opens the batch (so the head of the queue can never starve); younger
//!   segments that still fit in the remaining rows are pulled in, skipping
//!   over ones that don't. Relative order among skipped segments is
//!   preserved. Compatibility is structural: one coalescer serves one bank,
//!   and a bank fixes workload kind, model and geometry at service start,
//!   so every segment in the queue is packable with every other.
//! * **Full batches dispatch immediately.** Occupancy == rows never waits.
//! * **Linger window.** An underfull batch waits up to `linger` for
//!   co-tenants, counted from its oldest segment's arrival — a lone tiny
//!   job is delayed by at most one window, never forever. `flush` (service
//!   shutdown) overrides the wait, a full segment further back is never
//!   held behind an open window, and segments requeued after a worker
//!   death were already dispatchable once, so they never linger again.
//! * **Poison ships alone.** Fault-injection payloads simulate a crossbar
//!   dying mid-operation; co-batching one with real traffic would fail
//!   innocent jobs, so a poison segment is its own batch and an opaque
//!   barrier to packing across it.

use crate::coordinator::worker::{Payload, Segment};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

struct Pending {
    seg: Segment,
    /// Arrival time (the linger clock).
    since: Instant,
    /// Handed back unexecuted by a retiring worker: the segment already
    /// sat out a window once, so it never lingers again.
    requeued: bool,
}

fn is_poison(seg: &Segment) -> bool {
    matches!(seg.payload, Payload::Poison)
}

/// The dispatcher's pending-segment queue plus the packing policy.
pub struct Coalescer {
    /// Row capacity of one batch (the bank geometry's row count).
    rows: usize,
    /// How long an underfull batch may wait for co-tenants.
    linger: Duration,
    /// When false, every segment ships alone — the serialized ablation the
    /// coalescing bench measures against.
    enabled: bool,
    pending: VecDeque<Pending>,
}

impl Coalescer {
    pub fn new(rows: usize, linger: Duration, enabled: bool) -> Self {
        Self { rows, linger, enabled, pending: VecDeque::new() }
    }

    /// Shrink (or restore) the packing capacity. The quarantine layer calls
    /// this when stuck-at rows leave service: batches must pack to the
    /// bank's *healthy* row count, or every batch would need a remap pass.
    pub fn set_capacity(&mut self, rows: usize) {
        self.rows = rows;
    }

    /// Enqueue a freshly submitted segment (its linger clock starts now).
    pub fn push_back(&mut self, seg: Segment, now: Instant) {
        self.pending.push_back(Pending { seg, since: now, requeued: false });
    }

    /// Requeue segments handed back unexecuted (killed worker), ahead of
    /// everything already waiting and in their original relative order.
    /// They were already dispatchable once, so they are immediately
    /// dispatchable again — no second linger window.
    pub fn push_front(&mut self, segs: Vec<Segment>, now: Instant) {
        for seg in segs.into_iter().rev() {
            self.pending.push_front(Pending { seg, since: now, requeued: true });
        }
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Drop every pending segment whose job is dead, returning them so the
    /// dispatcher can resolve their outstanding-chunk accounting.
    pub fn drain_dead(&mut self, mut dead: impl FnMut(&Segment) -> bool) -> Vec<Segment> {
        let mut dropped = Vec::new();
        self.pending.retain_mut(|p| {
            if dead(&p.seg) {
                dropped.push(std::mem::replace(
                    &mut p.seg,
                    Segment { job: 0, offset: 0, payload: Payload::Pairs(Vec::new()), remaps: 0 },
                ));
                false
            } else {
                true
            }
        });
        dropped
    }

    /// When the head batch is underfull, the instant its linger window
    /// expires and it becomes dispatchable anyway. `None` when the queue is
    /// empty or coalescing is disabled (everything is dispatchable now).
    pub fn deadline(&self) -> Option<Instant> {
        if !self.enabled {
            return None;
        }
        self.pending.front().map(|p| if p.requeued { p.since } else { p.since + self.linger })
    }

    /// Pop the next dispatchable batch: a full batch whenever the queued
    /// segments fill `rows`; an underfull batch only once its oldest
    /// segment has lingered past the window, or when `flush` is set.
    /// Returns `None` when nothing is dispatchable yet.
    pub fn pop_batch(&mut self, now: Instant, flush: bool) -> Option<Vec<Segment>> {
        let (front_poison, front_span, oldest, front_requeued) = {
            let front = self.pending.front()?;
            (is_poison(&front.seg), front.seg.payload.len(), front.since, front.requeued)
        };
        // Poison ships alone; so does every segment when coalescing is off.
        // A full segment is its own batch, and an oversized one (which the
        // submit path never produces) ships alone too, so the worker can
        // reject it instead of it wedging the queue head forever.
        if front_poison || !self.enabled || front_span >= self.rows {
            return Some(vec![self.pending.pop_front().expect("front exists").seg]);
        }
        // Greedy first-fit scan. The front segment fits (checked above), so
        // the batch's linger clock is the front's arrival time.
        let mut take = Vec::new();
        let mut fill = 0usize;
        for (i, p) in self.pending.iter().enumerate() {
            if is_poison(&p.seg) {
                break; // never pack across a fault-injection barrier
            }
            let span = p.seg.payload.len();
            if fill + span <= self.rows {
                take.push(i);
                fill += span;
                if fill == self.rows {
                    break;
                }
            }
        }
        if fill < self.rows && !flush && !front_requeued && now < oldest + self.linger {
            // The head batch keeps lingering for co-tenants, but a full
            // segment further back needs no packing at all — ship it now
            // rather than stalling it (and an idle crossbar) behind a
            // younger window. The head's linger clock is unaffected, and a
            // poison barrier is still never crossed.
            for (i, p) in self.pending.iter().enumerate() {
                if is_poison(&p.seg) {
                    break;
                }
                if p.seg.payload.len() >= self.rows {
                    return Some(vec![self.pending.remove(i).expect("scanned index exists").seg]);
                }
            }
            return None;
        }
        let mut batch = Vec::with_capacity(take.len());
        for &i in take.iter().rev() {
            batch.push(self.pending.remove(i).expect("scanned index exists").seg);
        }
        batch.reverse();
        Some(batch)
    }

    /// Drop everything (bank death: the jobs are being failed wholesale, so
    /// per-segment accounting no longer matters).
    pub fn clear(&mut self) {
        self.pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(job: u64, span: usize) -> Segment {
        Segment { job, offset: 0, payload: Payload::Pairs(vec![(1, 1); span]), remaps: 0 }
    }

    fn poison() -> Segment {
        Segment { job: u64::MAX, offset: 0, payload: Payload::Poison, remaps: 0 }
    }

    fn spans(batch: &[Segment]) -> Vec<(u64, usize)> {
        batch.iter().map(|s| (s.job, s.payload.len())).collect()
    }

    #[test]
    fn full_batches_dispatch_immediately() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        for j in 0..8 {
            c.push_back(seg(j, 1), t0);
        }
        // No linger elapsed, but occupancy is full.
        let batch = c.pop_batch(t0, false).expect("full batch must not wait");
        assert_eq!(batch.len(), 8);
        assert!(c.is_empty());
    }

    #[test]
    fn underfull_batch_waits_for_linger_then_releases() {
        let t0 = Instant::now();
        let linger = Duration::from_millis(5);
        let mut c = Coalescer::new(8, linger, true);
        c.push_back(seg(1, 3), t0);
        assert!(c.pop_batch(t0, false).is_none(), "underfull batch must linger");
        assert_eq!(c.deadline(), Some(t0 + linger));
        // Window expired: the lone segment ships underfull.
        let batch = c.pop_batch(t0 + linger, false).expect("lingered batch must release");
        assert_eq!(spans(&batch), vec![(1, 3)]);
    }

    #[test]
    fn flush_overrides_linger() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        c.push_back(seg(1, 2), t0);
        let batch = c.pop_batch(t0, true).expect("flush releases underfull batches");
        assert_eq!(spans(&batch), vec![(1, 2)]);
    }

    #[test]
    fn first_fit_skips_oversized_and_preserves_order() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        c.push_back(seg(1, 5), t0); // opens the batch
        c.push_back(seg(2, 8), t0); // doesn't fit next to 5 → skipped
        c.push_back(seg(3, 3), t0); // fills the batch to 8
        let batch = c.pop_batch(t0, false).expect("batch fills to capacity");
        assert_eq!(spans(&batch), vec![(1, 5), (3, 3)]);
        // The skipped full-size segment is now the front and ships next.
        let batch = c.pop_batch(t0, false).expect("full segment is its own batch");
        assert_eq!(spans(&batch), vec![(2, 8)]);
        assert!(c.is_empty());
    }

    #[test]
    fn full_segment_is_not_stalled_by_a_lingering_head() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        c.push_back(seg(1, 3), t0); // underfull head, window open
        c.push_back(seg(2, 8), t0); // full: needs no packing
        // The full segment ships immediately; the head keeps lingering.
        let batch = c.pop_batch(t0, false).expect("full occupancy never waits");
        assert_eq!(spans(&batch), vec![(2, 8)]);
        assert!(c.pop_batch(t0, false).is_none(), "the head's window is still open");
        let batch = c.pop_batch(t0 + Duration::from_secs(3600), false).expect("lingered head releases");
        assert_eq!(spans(&batch), vec![(1, 3)]);
    }

    #[test]
    fn disabled_coalescer_ships_each_segment_alone() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), false);
        c.push_back(seg(1, 1), t0);
        c.push_back(seg(2, 1), t0);
        assert!(c.deadline().is_none(), "disabled coalescing never lingers");
        assert_eq!(spans(&c.pop_batch(t0, false).unwrap()), vec![(1, 1)]);
        assert_eq!(spans(&c.pop_batch(t0, false).unwrap()), vec![(2, 1)]);
    }

    #[test]
    fn poison_ships_alone_and_blocks_packing_across() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        c.push_back(seg(1, 2), t0);
        c.push_back(poison(), t0);
        c.push_back(seg(2, 6), t0);
        // Packing must not reach past the poison to grab job 2.
        assert!(c.pop_batch(t0, false).is_none(), "underfull head must not pack across poison");
        let batch = c.pop_batch(t0, true).expect("flushed head");
        assert_eq!(spans(&batch), vec![(1, 2)]);
        let batch = c.pop_batch(t0, false).expect("poison batch");
        assert!(matches!(batch[0].payload, Payload::Poison));
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn drain_dead_removes_only_dead_jobs() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        c.push_back(seg(1, 2), t0);
        c.push_back(seg(2, 2), t0);
        c.push_back(seg(1, 1), t0);
        let dropped = c.drain_dead(|s| s.job == 1);
        assert_eq!(dropped.len(), 2);
        assert!(dropped.iter().all(|s| s.job == 1));
        assert_eq!(c.len(), 1);
        assert_eq!(spans(&c.pop_batch(t0, true).unwrap()), vec![(2, 2)]);
    }

    #[test]
    fn requeued_segments_keep_their_order_at_the_front() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        c.push_back(seg(9, 8), t0);
        c.push_front(vec![seg(1, 4), seg(2, 4)], t0);
        let batch = c.pop_batch(t0, false).expect("requeued segments fill a batch");
        assert_eq!(spans(&batch), vec![(1, 4), (2, 4)]);
    }

    /// Quarantined rows shrink the packing capacity: batches fill to the
    /// healthy row count, not the physical one.
    #[test]
    fn shrunk_capacity_packs_to_healthy_rows() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        c.set_capacity(5);
        c.push_back(seg(1, 3), t0);
        c.push_back(seg(2, 3), t0); // no longer fits next to 3 at capacity 5
        c.push_back(seg(3, 2), t0);
        let batch = c.pop_batch(t0, false).expect("batch fills the shrunk capacity");
        assert_eq!(spans(&batch), vec![(1, 3), (3, 2)]);
    }

    /// A segment handed back by a dying worker already sat out its window
    /// once: it must be dispatchable again immediately, not re-linger.
    #[test]
    fn requeued_segments_do_not_relinger() {
        let t0 = Instant::now();
        let mut c = Coalescer::new(8, Duration::from_secs(3600), true);
        c.push_front(vec![seg(1, 2)], t0);
        assert_eq!(c.deadline(), Some(t0), "requeued work is due immediately");
        let batch = c.pop_batch(t0, false).expect("no second linger window");
        assert_eq!(spans(&batch), vec![(1, 2)]);
    }
}
