//! The fleet tier: many crossbar banks with *different* workloads behind
//! one front door — workload routing, admission control, and bank
//! lifecycle above the [`crate::coordinator::service::PimService`] layer.
//!
//! A single `PimService` fixes one `WorkloadKind`/model/geometry at start,
//! so mixed traffic (multiply + add + sort) could not share a deployment.
//! The [`PimFleet`] owns N banks, each its own fault-isolated scheduler,
//! and a cloneable [`FleetClient`] places every job:
//!
//! ```text
//!   clients ──submit(kind, ...)──▶ Router ──▶ bank 0  PimService (mul32)
//!      ▲           │ admission      │  ▶────▶ bank 1  PimService (add32)
//!      │           │ (Overloaded)   │  ▶────▶ bank 2  PimService (sort16)
//!      └── FleetJobHandle::wait ◀───┴─reroute on BankDead──▶ hot spare
//! ```
//!
//! * **Routing** is by workload compatibility first ([`WorkloadKind`] must
//!   match; shapes are checked with the same typed
//!   [`WorkloadMismatch`] the service layer uses), then by queue depth:
//!   among compatible live banks the one with the fewest unresolved jobs
//!   ([`PimService::pending_jobs`]) wins, so a slow bank sheds load to its
//!   peers instead of growing an unbounded queue.
//! * **Admission control**: when every compatible bank already holds
//!   `max_pending_per_bank` unresolved jobs, `submit` fails fast with a
//!   typed [`Overloaded`] error instead of queueing unboundedly — the
//!   backpressure contract callers retry against.
//! * **Bank lifecycle**: a bank whose last worker died is discovered
//!   lazily (by the router, or by a job failing with the typed
//!   [`BankDead`] error) and retired; its unresolved jobs are requeued
//!   onto a compatible bank — or onto a hot spare promoted on the spot.
//!   Promotion is warm: workload programs live in the process-wide
//!   [`compile_workload_cached`], so a spare starts serving without
//!   recompiling anything. An elastic policy additionally spawns/retires
//!   banks per workload from arrival rates (see [`ElasticPolicy`]).
//! * **Statistics**: [`FleetStats`] merges the per-bank [`ServiceStats`]
//!   of every live, dead and retired bank, plus fleet-level counters
//!   (routed / rejected / rerouted / promoted / spawned / retired).

use crate::coordinator::service::{BankDead, JobHandle, JobResult, PimService, ServiceConfig, ServiceStats, WorkloadMismatch};
use crate::coordinator::worker::{compile_workload_cached, workload_geometry, Payload, WorkloadKind};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Typed admission-control error: every bank compatible with the job's
/// workload is already at the configured pending-job bound. The job was
/// *not* queued — callers own the retry policy (back off, shed, or retry
/// against a later, less loaded fleet).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Overloaded {
    /// The workload that could not be admitted.
    pub kind: WorkloadKind,
    /// Queue depth of the least-loaded compatible bank at rejection time.
    pub pending: usize,
    /// The configured bound ([`FleetConfig::max_pending_per_bank`]).
    pub limit: usize,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fleet overloaded: every {} bank is at the admission bound ({} pending >= limit {})",
            self.kind.name(),
            self.pending,
            self.limit
        )
    }
}

impl std::error::Error for Overloaded {}

/// Typed routing error: no active bank in the fleet serves this workload
/// (and no spare could be promoted for it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NoCompatibleBank {
    pub kind: WorkloadKind,
}

impl std::fmt::Display for NoCompatibleBank {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "no active bank serves the {} workload", self.kind.name())
    }
}

impl std::error::Error for NoCompatibleBank {}

/// Elastic spawn/retire policy, driven by per-workload arrival rates over
/// a sliding window. Disabled by default: the fleet then keeps exactly the
/// banks it was started with (plus hot-spare promotions).
#[derive(Debug, Clone, Copy)]
pub struct ElasticPolicy {
    pub enabled: bool,
    /// Arrival-rate measurement window.
    pub window: Duration,
    /// Arrivals one bank is expected to absorb per window; the target bank
    /// count for a workload is `ceil(arrivals / jobs_per_bank_window)`,
    /// never below one (a served workload stays servable).
    pub jobs_per_bank_window: usize,
    /// Hard cap on concurrently active banks across the whole fleet.
    pub max_banks: usize,
}

impl Default for ElasticPolicy {
    fn default() -> Self {
        Self {
            enabled: false,
            window: Duration::from_secs(1),
            jobs_per_bank_window: 64,
            max_banks: 8,
        }
    }
}

/// Fleet configuration: the initial bank set plus the routing, admission
/// and lifecycle policies.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// One entry per initial bank; each bank may have its own workload,
    /// model and geometry.
    pub banks: Vec<ServiceConfig>,
    /// Hot-spare capacity: how many replacement banks may be promoted when
    /// banks die. A spare is a capacity token, not a running service — on
    /// promotion it starts with the dead bank's exact config, warm from
    /// the process-wide compile cache.
    pub spare_slots: usize,
    /// Admission bound per bank (see [`Overloaded`]).
    pub max_pending_per_bank: usize,
    /// How many times one job may be rerouted after bank deaths before its
    /// failure is surfaced to the caller.
    pub max_reroutes: usize,
    pub elastic: ElasticPolicy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            banks: Vec::new(),
            spare_slots: 0,
            max_pending_per_bank: 256,
            max_reroutes: 2,
            elastic: ElasticPolicy::default(),
        }
    }
}

impl FleetConfig {
    /// A mixed-workload fleet: `n_banks` banks cycling through `mix`, all
    /// sharing one model and geometry. The shape the serve CLI and the
    /// fleet bench build (`--banks N --mix mul:add:sort`).
    pub fn mixed(mix: &[WorkloadKind], n_banks: usize, base: ServiceConfig) -> Result<FleetConfig> {
        ensure!(!mix.is_empty(), "empty workload mix");
        ensure!(n_banks >= 1, "need at least one bank");
        let banks = (0..n_banks).map(|i| ServiceConfig { kind: mix[i % mix.len()], ..base }).collect();
        Ok(FleetConfig { banks, ..Default::default() })
    }
}

/// Where a bank slot is in its lifecycle. Slots are never removed from the
/// fleet's table (indices stay stable for in-flight handles); they change
/// state instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BankState {
    /// Serving traffic.
    Active,
    /// Every worker died; unresolved jobs were failed by the service layer
    /// (with the typed [`BankDead`]) and rerouted by their fleet handles.
    Dead,
    /// Drained and stopped deliberately (elastic scale-down).
    Retired,
}

struct BankSlot {
    cfg: ServiceConfig,
    /// `None` once the bank is dead or retired.
    service: Option<PimService>,
    state: BankState,
    /// Final statistics of a dead/retired bank (folded into `FleetStats`).
    final_stats: Option<ServiceStats>,
}

/// Fleet-level event counters (routing, backpressure, lifecycle).
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetCounters {
    /// Jobs accepted and placed on a bank (including reroutes).
    pub routed: u64,
    /// Submissions rejected by admission control ([`Overloaded`]).
    pub rejected_overloaded: u64,
    /// Submissions rejected because no bank serves the workload.
    pub rejected_no_bank: u64,
    /// Jobs requeued onto another bank after their bank died.
    pub reroutes: u64,
    /// Hot spares promoted to replace dead banks.
    pub spares_promoted: u64,
    /// Banks spawned by the elastic policy.
    pub banks_spawned: u64,
    /// Banks retired by the elastic policy.
    pub banks_retired: u64,
    /// Banks that died (all workers lost).
    pub banks_dead: u64,
}

/// Point-in-time view of one bank.
#[derive(Debug, Clone)]
pub struct BankSnapshot {
    pub kind: WorkloadKind,
    pub state: BankState,
    pub pending_jobs: usize,
    pub live_workers: usize,
    pub stats: ServiceStats,
}

/// Fleet-wide statistics: the merged per-bank [`ServiceStats`] plus the
/// per-bank snapshots and the fleet-level counters.
#[derive(Debug, Clone)]
pub struct FleetStats {
    /// Every bank's `ServiceStats` merged (live, dead and retired).
    pub aggregate: ServiceStats,
    pub banks: Vec<BankSnapshot>,
    pub counters: FleetCounters,
}

struct FleetInner {
    banks: Vec<BankSlot>,
    spare_slots: usize,
    counters: FleetCounters,
    /// Per-workload arrival timestamps inside the elastic window (only
    /// tracked while the elastic policy is enabled).
    arrivals: HashMap<WorkloadKind, VecDeque<Instant>>,
}

struct FleetShared {
    cfg: FleetConfig,
    inner: Mutex<FleetInner>,
}

impl FleetShared {
    /// Fold a bank that lost its last worker: mark it dead, collect its
    /// final statistics, and — if a spare slot is available — promote a
    /// replacement with the same config (warm from the compile cache).
    /// Idempotent: only the first caller transitions the slot.
    fn note_bank_death(&self, inner: &mut FleetInner, bank: usize) {
        let slot = &mut inner.banks[bank];
        if slot.state != BankState::Active {
            return;
        }
        slot.state = BankState::Dead;
        inner.counters.banks_dead += 1;
        if let Some(mut svc) = slot.service.take() {
            // Dead-bank drain is fast: every pending job has already been
            // failed by the service layer, so only thread joins remain.
            slot.final_stats = Some(svc.drain());
        }
        let cfg = slot.cfg;
        if inner.spare_slots > 0 {
            inner.spare_slots -= 1;
            match PimService::start(cfg) {
                Ok(svc) => {
                    inner.banks.push(BankSlot {
                        cfg,
                        service: Some(svc),
                        state: BankState::Active,
                        final_stats: None,
                    });
                    inner.counters.spares_promoted += 1;
                }
                // Promotion failed (should not happen for a config that
                // already ran): give the slot back rather than leaking it.
                Err(_) => inner.spare_slots += 1,
            }
        }
    }

    /// Notice banks whose last worker died since the previous pass, so the
    /// router never places new work on a dead bank and spares are promoted
    /// even before any in-flight handle observes the death.
    fn reap_dead(&self, inner: &mut FleetInner) {
        for i in 0..inner.banks.len() {
            let dead = match &inner.banks[i].service {
                Some(svc) => inner.banks[i].state == BankState::Active && svc.live_workers() == 0,
                None => false,
            };
            if dead {
                self.note_bank_death(inner, i);
            }
        }
    }

    /// Pick the compatible active bank with the fewest unresolved jobs.
    /// With `enforce_admission`, reject with [`Overloaded`] when even that
    /// bank is at the bound (reroutes skip admission: the job was already
    /// accepted once — backpressure applies at the front door only).
    fn route(&self, inner: &mut FleetInner, kind: WorkloadKind, enforce_admission: bool) -> Result<usize> {
        self.reap_dead(inner);
        let mut best: Option<(usize, usize)> = None;
        for (i, slot) in inner.banks.iter().enumerate() {
            if slot.state != BankState::Active || slot.cfg.kind != kind {
                continue;
            }
            let Some(svc) = &slot.service else { continue };
            let pending = svc.pending_jobs();
            let better = match best {
                Some((p, _)) => pending < p,
                None => true,
            };
            if better {
                best = Some((pending, i));
            }
        }
        let Some((pending, idx)) = best else {
            inner.counters.rejected_no_bank += 1;
            return Err(anyhow::Error::new(NoCompatibleBank { kind }));
        };
        if enforce_admission && pending >= self.cfg.max_pending_per_bank {
            inner.counters.rejected_overloaded += 1;
            return Err(anyhow::Error::new(Overloaded { kind, pending, limit: self.cfg.max_pending_per_bank }));
        }
        Ok(idx)
    }

    fn submit_to(&self, inner: &FleetInner, bank: usize, kind: WorkloadKind, payload: &Payload) -> Result<JobHandle> {
        let svc = inner.banks[bank].service.as_ref().context("routed to a bank without a service")?;
        svc.submit_job(kind, payload.clone())
    }

    /// Front-door submission: note the arrival, autoscale opportunistically,
    /// route under admission control, and place the job. The payload is
    /// retained in the returned handle so the job can be requeued onto
    /// another bank if its bank dies before completing it (re-execution is
    /// idempotent: jobs are pure computations over their operands).
    fn submit_payload(self: &Arc<Self>, kind: WorkloadKind, payload: Payload) -> Result<FleetJobHandle> {
        let mut inner = self.inner.lock().unwrap();
        if self.cfg.elastic.enabled {
            let now = Instant::now();
            let q = inner.arrivals.entry(kind).or_default();
            q.push_back(now);
            while q.front().is_some_and(|&t| now.duration_since(t) > self.cfg.elastic.window) {
                q.pop_front();
            }
            self.autoscale_locked(&mut inner);
        }
        let bank = self.route(&mut inner, kind, true)?;
        let handle = self.submit_to(&inner, bank, kind, &payload)?;
        inner.counters.routed += 1;
        Ok(FleetJobHandle {
            shared: Arc::clone(self),
            kind,
            payload,
            current: Some((bank, handle)),
            reroutes_left: self.cfg.max_reroutes,
        })
    }

    /// Requeue a job whose bank died: retire the bank (promoting a spare if
    /// one is available) and place the job on a compatible bank.
    fn note_death_and_resubmit(&self, bank: usize, kind: WorkloadKind, payload: &Payload) -> Result<(usize, JobHandle)> {
        let mut inner = self.inner.lock().unwrap();
        self.note_bank_death(&mut inner, bank);
        let idx = self.route(&mut inner, kind, false)?;
        let handle = self.submit_to(&inner, idx, kind, payload)?;
        inner.counters.routed += 1;
        inner.counters.reroutes += 1;
        Ok((idx, handle))
    }

    /// Elastic pass (lock held): per workload, spawn banks while the
    /// arrival rate outruns capacity and retire *idle* banks when it has
    /// fallen back, never dropping a served workload to zero banks and
    /// never exceeding `max_banks` active banks fleet-wide.
    fn autoscale_locked(&self, inner: &mut FleetInner) {
        let policy = self.cfg.elastic;
        if !policy.enabled {
            return;
        }
        let now = Instant::now();
        for q in inner.arrivals.values_mut() {
            while q.front().is_some_and(|&t| now.duration_since(t) > policy.window) {
                q.pop_front();
            }
        }
        let kinds: Vec<WorkloadKind> = WorkloadKind::ALL
            .into_iter()
            .filter(|k| {
                inner.banks.iter().any(|b| b.cfg.kind == *k) || inner.arrivals.get(k).is_some_and(|q| !q.is_empty())
            })
            .collect();
        for kind in kinds {
            let arrivals = inner.arrivals.get(&kind).map_or(0, |q| q.len());
            let desired = arrivals.div_ceil(policy.jobs_per_bank_window).max(1);
            loop {
                let active: Vec<usize> = inner
                    .banks
                    .iter()
                    .enumerate()
                    .filter(|(_, b)| b.state == BankState::Active && b.cfg.kind == kind)
                    .map(|(i, _)| i)
                    .collect();
                let total_active = inner.banks.iter().filter(|b| b.state == BankState::Active).count();
                if active.len() < desired && total_active < policy.max_banks {
                    // Spawn: reuse the config of any slot that served this
                    // workload (warm from the compile cache). A workload
                    // that never had a bank has no config to clone — the
                    // router rejects it as NoCompatibleBank regardless.
                    let Some(cfg) = inner.banks.iter().find(|b| b.cfg.kind == kind).map(|b| b.cfg) else { break };
                    let Ok(svc) = PimService::start(cfg) else { break };
                    inner.banks.push(BankSlot {
                        cfg,
                        service: Some(svc),
                        state: BankState::Active,
                        final_stats: None,
                    });
                    inner.counters.banks_spawned += 1;
                } else if active.len() > desired {
                    // Retire: only a bank with nothing unresolved, so the
                    // drain is instant and no handle is interrupted.
                    let Some(&idx) = active.iter().find(|&&i| {
                        inner.banks[i].service.as_ref().is_some_and(|s| s.pending_jobs() == 0)
                    }) else {
                        break; // all busy; retire on a later pass
                    };
                    let slot = &mut inner.banks[idx];
                    slot.state = BankState::Retired;
                    if let Some(mut svc) = slot.service.take() {
                        slot.final_stats = Some(svc.drain());
                    }
                    inner.counters.banks_retired += 1;
                } else {
                    break;
                }
            }
        }
    }

    fn stats_locked(&self, inner: &mut FleetInner) -> FleetStats {
        self.reap_dead(inner);
        let mut aggregate = ServiceStats::default();
        let mut banks = Vec::with_capacity(inner.banks.len());
        for slot in &inner.banks {
            let (stats, pending, live) = match &slot.service {
                Some(svc) => (svc.stats(), svc.pending_jobs(), svc.live_workers()),
                None => (slot.final_stats.unwrap_or_default(), 0, 0),
            };
            aggregate.merge(&stats);
            banks.push(BankSnapshot {
                kind: slot.cfg.kind,
                state: slot.state,
                pending_jobs: pending,
                live_workers: live,
                stats,
            });
        }
        FleetStats { aggregate, banks, counters: inner.counters }
    }
}

/// A multi-bank PIM fleet: start with [`PimFleet::start`], submit through
/// [`PimFleet::client`] (cloneable, `Send`), inspect with
/// [`PimFleet::stats`], stop with [`PimFleet::shutdown`].
pub struct PimFleet {
    shared: Arc<FleetShared>,
}

impl PimFleet {
    /// Start every configured bank and pre-warm the process-wide compile
    /// cache for each distinct workload, so later hot-spare promotions and
    /// elastic spawns pay no compilation.
    pub fn start(cfg: FleetConfig) -> Result<Self> {
        ensure!(!cfg.banks.is_empty(), "a fleet needs at least one bank");
        for bank in &cfg.banks {
            let geom = workload_geometry(bank.kind, bank.model, bank.rows)?;
            compile_workload_cached(bank.kind, bank.model, geom)
                .with_context(|| format!("pre-warming the {} workload", bank.kind.name()))?;
        }
        let mut banks = Vec::with_capacity(cfg.banks.len());
        for bank in &cfg.banks {
            banks.push(BankSlot {
                cfg: *bank,
                service: Some(PimService::start(*bank)?),
                state: BankState::Active,
                final_stats: None,
            });
        }
        let inner = FleetInner {
            banks,
            spare_slots: cfg.spare_slots,
            counters: FleetCounters::default(),
            arrivals: HashMap::new(),
        };
        Ok(Self { shared: Arc::new(FleetShared { cfg, inner: Mutex::new(inner) }) })
    }

    /// A cloneable submission front-end.
    pub fn client(&self) -> FleetClient {
        FleetClient { shared: Arc::clone(&self.shared) }
    }

    /// Submit any job through the unified path (see
    /// [`FleetClient::submit_job`]).
    pub fn submit_job(&self, kind: WorkloadKind, payload: Payload) -> Result<FleetJobHandle> {
        self.client().submit_job(kind, payload)
    }

    /// Submit an element-wise job (see [`FleetClient::submit`]).
    pub fn submit(&self, kind: WorkloadKind, a: &[u64], b: &[u64]) -> Result<FleetJobHandle> {
        self.client().submit(kind, a, b)
    }

    /// Submit a per-row sort job (see [`FleetClient::submit_sort`]).
    pub fn submit_sort(&self, rows_data: &[Vec<u64>]) -> Result<FleetJobHandle> {
        self.client().submit_sort(rows_data)
    }

    /// Point-in-time fleet statistics.
    pub fn stats(&self) -> FleetStats {
        let mut inner = self.shared.inner.lock().unwrap();
        self.shared.stats_locked(&mut inner)
    }

    /// Active banks right now (after noticing any deaths).
    pub fn active_banks(&self) -> usize {
        let mut inner = self.shared.inner.lock().unwrap();
        self.shared.reap_dead(&mut inner);
        inner.banks.iter().filter(|b| b.state == BankState::Active).count()
    }

    /// Run one elastic pass now (the pass also runs opportunistically on
    /// every submission; this is for draining capacity while idle).
    pub fn autoscale(&self) {
        let mut inner = self.shared.inner.lock().unwrap();
        self.shared.reap_dead(&mut inner);
        self.shared.autoscale_locked(&mut inner);
    }

    /// Fault injection: abruptly kill every worker of bank `bank`, as if
    /// the whole crossbar bank lost power. The death is *discovered* the
    /// way a real one would be: by the router on the next submission, or
    /// by an in-flight handle failing with [`BankDead`] and rerouting.
    pub fn kill_bank(&self, bank: usize) -> Result<()> {
        let inner = self.shared.inner.lock().unwrap();
        let slot = inner.banks.get(bank).with_context(|| format!("no bank {bank} in a fleet of {}", inner.banks.len()))?;
        ensure!(slot.state == BankState::Active, "bank {bank} is not active");
        let svc = slot.service.as_ref().context("active bank without a service")?;
        for w in 0..slot.cfg.n_crossbars {
            let _ = svc.kill_worker(w);
        }
        Ok(())
    }

    /// Drain every bank (in-flight jobs finish first) and return the final
    /// fleet statistics.
    pub fn shutdown(self) -> FleetStats {
        let mut inner = self.shared.inner.lock().unwrap();
        for slot in &mut inner.banks {
            if let Some(mut svc) = slot.service.take() {
                slot.final_stats = Some(svc.drain());
                if slot.state == BankState::Active {
                    slot.state = BankState::Retired;
                }
            }
        }
        self.shared.stats_locked(&mut inner)
    }
}

/// A cloneable, `Send` fleet submission front-end — the fleet-level
/// counterpart of [`crate::coordinator::service::PimClient`].
#[derive(Clone)]
pub struct FleetClient {
    shared: Arc<FleetShared>,
}

impl FleetClient {
    /// The single fleet submission path: place `payload` on the
    /// least-loaded active bank serving `kind`. Fails fast with the typed
    /// [`Overloaded`] under backpressure, [`NoCompatibleBank`] if no bank
    /// serves `kind`, and [`WorkloadMismatch`] if the payload's shape does
    /// not match the workload's. The shape-specific `submit`/`submit_sort`
    /// entry points are one-line wrappers over this.
    pub fn submit_job(&self, kind: WorkloadKind, payload: Payload) -> Result<FleetJobHandle> {
        let Some(shape) = payload.shape() else {
            bail!("fault-injection payloads cannot be submitted as jobs");
        };
        if shape != kind.shape() {
            return Err(anyhow::Error::new(WorkloadMismatch { service: kind, submitted: shape }));
        }
        self.shared.submit_payload(kind, payload)
    }

    /// Submit an element-wise job for `kind` (`Mul32` or `Add32`); the
    /// router picks the least-loaded compatible bank.
    pub fn submit(&self, kind: WorkloadKind, a: &[u64], b: &[u64]) -> Result<FleetJobHandle> {
        self.submit_job(kind, Payload::pairs(a, b)?)
    }

    /// Submit a per-row sort job (routes to a `Sort16` bank).
    pub fn submit_sort(&self, rows_data: &[Vec<u64>]) -> Result<FleetJobHandle> {
        self.submit_job(WorkloadKind::Sort16, Payload::Rows(rows_data.to_vec()))
    }

    /// Submit a Keccak-f[1600] permutation job, one 25-lane state per row
    /// (routes to a `Sha3` bank).
    pub fn submit_sha3(&self, states: &[[u64; 25]]) -> Result<FleetJobHandle> {
        self.submit_job(WorkloadKind::Sha3, Payload::States(states.to_vec()))
    }
}

/// A pending fleet job. Unlike the service-level
/// [`JobHandle`], this handle owns the job's operands and
/// requeues the job onto a compatible bank (or a freshly promoted hot
/// spare) when its bank dies mid-flight — the caller only ever sees the
/// failure once the reroute budget is exhausted or no compatible bank is
/// left.
pub struct FleetJobHandle {
    shared: Arc<FleetShared>,
    kind: WorkloadKind,
    payload: Payload,
    current: Option<(usize, JobHandle)>,
    reroutes_left: usize,
}

impl FleetJobHandle {
    /// The bank currently executing the job.
    pub fn bank(&self) -> Option<usize> {
        self.current.as_ref().map(|(b, _)| *b)
    }

    /// Block until the job completes, transparently rerouting it if its
    /// bank dies (the typed [`BankDead`] error is consumed here; any other
    /// failure is the job's own and is surfaced as-is).
    pub fn wait(mut self) -> Result<JobResult> {
        loop {
            let (bank, handle) = self.current.take().context("fleet job handle already consumed")?;
            match handle.wait() {
                Ok(r) => return Ok(r),
                Err(e) => self.current = Some(self.reroute(bank, e)?),
            }
        }
    }

    /// Bounded wait: `None` if the job is still in flight when `timeout`
    /// expires, leaving the handle usable. A bank death during the wait
    /// still triggers a reroute (and the wait continues on the new bank
    /// within the same deadline).
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<Result<JobResult>> {
        let deadline = Instant::now() + timeout;
        loop {
            let Some((bank, handle)) = &self.current else {
                return Some(Err(anyhow!("fleet job handle already consumed")));
            };
            let bank = *bank;
            match handle.wait_timeout(deadline.saturating_duration_since(Instant::now())) {
                None => return None,
                Some(Ok(r)) => {
                    self.current = None;
                    return Some(Ok(r));
                }
                Some(Err(e)) => {
                    self.current = None;
                    match self.reroute(bank, e) {
                        Ok(cur) => self.current = Some(cur),
                        Err(e) => return Some(Err(e)),
                    }
                }
            }
        }
    }

    /// Requeue after a bank death; any other error (or an exhausted
    /// reroute budget) is final.
    fn reroute(&mut self, bank: usize, e: anyhow::Error) -> Result<(usize, JobHandle)> {
        if e.downcast_ref::<BankDead>().is_none() || self.reroutes_left == 0 {
            return Err(e);
        }
        self.reroutes_left -= 1;
        self.shared
            .note_death_and_resubmit(bank, self.kind, &self.payload)
            .with_context(|| format!("requeueing the job after bank {bank} died"))
    }
}
