//! The L3 coordinator: a controller + crossbar-bank runtime serving vectored
//! arithmetic jobs over the partitioned-PIM substrate.
//!
//! Architecture (mirroring a PIM memory controller [4, 19]):
//!
//! ```text
//!   clients ──submit──▶ Controller ──chunks──▶ Worker 0 (crossbar 0)
//!                        │  dynamic batching    Worker 1 (crossbar 1)
//!                        ◀──results/metrics───  ...
//! ```
//!
//! * Jobs are element-wise vector operations (32-bit multiply / add);
//!   each crossbar **row** processes one element pair independently — the
//!   single-row parallelism stateful logic provides for free.
//! * The controller batches job elements into row-chunks and dispatches them
//!   round-robin to worker threads, each owning one simulated crossbar.
//! * Workers stream the compiled program **as encoded control messages**
//!   through the periphery decode path (the production path), so control
//!   traffic, cycles and energy are metered exactly as the paper counts them.
//!
//! The environment has no tokio vendored, so the runtime is `std::thread` +
//! `mpsc` channels (see DESIGN.md §Substitutions); the architecture is
//! unchanged.

pub mod service;
pub mod worker;

pub use service::{JobResult, PimService, ServiceConfig, ServiceStats};
pub use worker::WorkloadKind;
