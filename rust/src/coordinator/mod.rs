//! The L3 coordinator: a concurrent, fault-isolated scheduler serving
//! vectored arithmetic jobs over the partitioned-PIM substrate.
//!
//! Architecture (mirroring a PIM memory controller [4, 19]):
//!
//! ```text
//!   clients ──submit──▶ JobHandle      Dispatcher ──pull──▶ Worker 0 (crossbar 0)
//!                │                       │  job table        Worker 1 (crossbar 1)
//!                └──Register + chunks──▶ │  chunk queue      ...
//!                       ◀──JobResult─────┴──Done / Exit◀──── results, faults
//! ```
//!
//! * Jobs are element-wise vector operations (32-bit multiply / add),
//!   per-row sorts, or per-row Keccak-f[1600] permutations (the HashPIM
//!   SHA-3 datapath); each crossbar **row** processes one element (pair /
//!   vector / state) independently — the single-row parallelism stateful
//!   logic provides for free.
//! * [`PimService::submit`] is non-blocking and returns a [`JobHandle`], so
//!   any number of jobs are in flight at once; a central dispatcher routes
//!   completions back by job id and assigns work to *idle* workers (pull
//!   model). [`PimService::client`] hands out cloneable `Send` submission
//!   front-ends for multi-threaded clients.
//! * Before work reaches a worker it passes the [`coalesce::Coalescer`]:
//!   partial row-chunks from different jobs pack into one shared
//!   full-occupancy batch (the crossbar is row-parallel, so a batch costs
//!   the same at any occupancy — shipping small jobs alone wasted almost
//!   the entire bank). Per-job metrics are attributed per segment:
//!   occupancy-proportional cycles/control traffic, exact row-range
//!   switching energy.
//! * Faults are isolated per segment, per batch and per worker: a malformed
//!   operand fails only its own job while co-batched segments complete (the
//!   worker keeps serving), a crashed worker retires from the bank and the
//!   batch it had not executed is requeued to the survivors (see DESIGN.md
//!   §Coordinator).
//! * Workers replay the compiled program through the **decode-once trusted
//!   op cache** ([`prepared_workload_cached`], shared per (kind, model,
//!   geometry)): the wire stream is encoded and periphery-decoded a single
//!   time, every batch replays the trusted operations, and the cached
//!   control-traffic cost is charged per replay — so control traffic,
//!   cycles and energy are metered exactly as the paper counts them while
//!   the hot loop skips the per-batch decoder (DESIGN.md §Replay fast
//!   path). `ServiceConfig::replay_mode` forces the full wire re-decode
//!   for differential testing, and `replay_threads` spreads each replay
//!   over parallel word ranges.
//!
//! * Above single banks sits the [`fleet`] tier: a [`fleet::PimFleet`]
//!   owns many `PimService` banks with *different* workloads behind one
//!   cloneable [`fleet::FleetClient`], routing each job by workload
//!   compatibility and queue depth, bounding queues with a typed
//!   [`fleet::Overloaded`] backpressure error, and absorbing bank death
//!   by rerouting onto peers or warm-promoted hot spares (see DESIGN.md
//!   §Fleet).
//! * Serving is **wear- and reliability-aware**: every bank keeps a
//!   persistent per-row [`crate::crossbar::WearMap`] fed by exact
//!   switch-event attribution, placement prefers cold rows
//!   (`ServiceConfig::wear_leveling`), stuck-at faults detected mid-batch
//!   quarantine the row and transparently remap the affected segments onto
//!   healthy rows within a bounded retry budget (typed
//!   [`service::RowQuarantined`] once capacity is exhausted), and
//!   [`ServiceStats`] carries an endurance-horizon summary (max/mean row
//!   wear, wear Gini, projected time-to-first-failure under
//!   `ServiceConfig::endurance_budget`) — DESIGN.md §Wear.
//! * Every tier submits through one typed front door:
//!   `submit_job(kind, `[`worker::Payload`]`)` on [`PimService`],
//!   [`PimClient`], [`fleet::FleetClient`] and [`fleet::PimFleet`]; the
//!   shape-specific `submit`/`submit_sort` entry points are one-line
//!   wrappers over it.
//!
//! The environment has no tokio vendored, so the runtime is `std::thread` +
//! `mpsc` channels (see DESIGN.md §Substitutions); the architecture is
//! unchanged.

pub mod coalesce;
pub mod fleet;
pub mod service;
pub mod worker;

pub use fleet::{
    BankSnapshot, BankState, ElasticPolicy, FleetClient, FleetConfig, FleetCounters, FleetJobHandle, FleetStats, NoCompatibleBank, Overloaded,
    PimFleet,
};
pub use service::{
    BankDead, JobHandle, JobResult, JobValues, PimClient, PimService, RowQuarantined, ServiceConfig, ServiceStats, ValueShapeMismatch,
    WorkloadMismatch,
};
pub use worker::{
    compile_workload, compile_workload_cached, prepared_workload_cached, workload_geometry, JobShape, Payload, Segment,
    SegmentReport, WorkloadKind,
};
