//! Crossbar worker: owns one simulated crossbar plus the compiled program
//! for its workload, and executes row-batches end-to-end through the
//! production control pipeline (encode → periphery decode → execute).

use crate::algorithms::addition::{build_adder, build_adder_aligned, Adder, AlignedAdder};
use crate::algorithms::mult_serial::{build_serial_multiplier, SerialMultiplier};
use crate::algorithms::multpim::{build_multpim, MultPim, MultPimVariant};
use crate::algorithms::program::Program;
use crate::algorithms::sha3::{build_keccak_f, Sha3Unit, LANES as SHA3_LANES};
use crate::backend::{ExecPipeline, PreparedProgram, ReplayMode};
use crate::crossbar::crossbar::{Crossbar, Metrics};
use crate::crossbar::faults::FaultMap;
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::models::ModelKind;
use crate::isa::schedule::pack_program;
use crate::verify;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Which vectored operation this service instance executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// Element-wise 32-bit multiply via the partitioned MultPIM program
    /// (or the serial baseline when the model is `Baseline`).
    Mul32,
    /// Element-wise 32-bit add (serial single-row ripple adder).
    Add32,
    /// Per-row sort of 16 six-bit elements (partitioned bitonic network;
    /// serial network on the baseline).
    Sort16,
    /// Per-row Keccak-f[1600] permutation (the HashPIM SHA-3 datapath,
    /// bit-sliced along z — one partition per lane bit) in the
    /// NOT/NOR/OR/XOR gate set.
    Sha3,
}

/// The shape of a job's operands, mirroring [`Payload`]: element-wise
/// scalar pairs, or one element vector per crossbar row. The fleet router
/// and the typed `WorkloadMismatch` error speak in shapes — a submission is
/// routable onto a bank exactly when the shapes agree.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobShape {
    /// `(a, b)` scalar pairs, one result scalar per element.
    ElementWise,
    /// One element vector per row, one result vector per row.
    RowVectors,
    /// One 25-lane Keccak state per row, one permuted state per row.
    KeccakState,
}

impl std::fmt::Display for JobShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            JobShape::ElementWise => "element-wise pairs",
            JobShape::RowVectors => "per-row vectors",
            JobShape::KeccakState => "per-row keccak states",
        })
    }
}

impl WorkloadKind {
    /// Every workload the bank layer can serve — the fleet's routing table
    /// iterates this, and `repro lint` sweeps it.
    pub const ALL: [WorkloadKind; 4] = [WorkloadKind::Mul32, WorkloadKind::Add32, WorkloadKind::Sort16, WorkloadKind::Sha3];

    /// Stable name (CLI flags, bench JSON, fleet reports).
    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Mul32 => "mul32",
            WorkloadKind::Add32 => "add32",
            WorkloadKind::Sort16 => "sort16",
            WorkloadKind::Sha3 => "sha3",
        }
    }

    /// Parse a CLI spelling (`mul`/`mul32`, `add`/`add32`, `sort`/`sort16`,
    /// `sha3`).
    pub fn parse(s: &str) -> Option<WorkloadKind> {
        match s {
            "mul" | "mul32" => Some(WorkloadKind::Mul32),
            "add" | "add32" => Some(WorkloadKind::Add32),
            "sort" | "sort16" => Some(WorkloadKind::Sort16),
            "sha3" | "sha-3" | "keccak" => Some(WorkloadKind::Sha3),
            _ => None,
        }
    }

    /// Operand shape this workload executes (the routing compatibility key).
    pub fn shape(self) -> JobShape {
        match self {
            WorkloadKind::Mul32 | WorkloadKind::Add32 => JobShape::ElementWise,
            WorkloadKind::Sort16 => JobShape::RowVectors,
            WorkloadKind::Sha3 => JobShape::KeccakState,
        }
    }

    /// The stateful-logic gate set this workload's program is built from.
    /// SHA-3 uses the HashPIM NOT/NOR/OR/XOR set (its wire messages carry
    /// the 2-bit per-cycle gate-type field); everything else runs the
    /// paper's NOT/NOR configuration with bit-identical untyped messages.
    pub fn gate_set(self) -> GateSet {
        match self {
            WorkloadKind::Sha3 => GateSet::HashPim,
            _ => GateSet::NotNor,
        }
    }
}

/// Elements a sort job handles per row.
pub const SORT_ELEMS: usize = 16;
/// Element width of the sort workload.
pub const SORT_BITS: usize = 6;

/// A job's operand payload: scalar pairs for element-wise arithmetic,
/// per-row element vectors for sort jobs. This is the single typed payload
/// of the `submit_job(kind, payload)` entry points on `PimService`,
/// `PimClient` and `FleetClient`; new workload families (e.g. a hashing
/// state vector) extend this enum rather than adding parallel submit
/// methods on every tier.
#[derive(Debug, Clone)]
pub enum Payload {
    Pairs(Vec<(u64, u64)>),
    Rows(Vec<Vec<u64>>),
    /// One 25-lane Keccak-f[1600] state per row (sha3 jobs).
    States(Vec<[u64; SHA3_LANES]>),
    /// Fault injection: executing this payload panics the worker thread,
    /// simulating a crossbar that dies mid-operation (used by the
    /// scheduler's resilience tests and `PimService::inject_worker_panic`).
    #[doc(hidden)]
    Poison,
}

/// One job's slice of a shared row-batch. The coalescer packs segments from
/// several compatible jobs (a service fixes workload kind, model and
/// geometry, so every job on one bank is compatible) into a single batch up
/// to full row occupancy; the worker executes the batch once and reads each
/// segment back from its own row range.
#[derive(Debug, Clone)]
pub struct Segment {
    /// Owning job id (completion routing key).
    pub job: u64,
    /// Element offset within the owning job's result accumulator.
    pub offset: usize,
    pub payload: Payload,
    /// Times this segment has been remapped off quarantined rows — the
    /// dispatcher's bounded stuck-at retry budget (`ServiceConfig::max_remaps`).
    pub remaps: u32,
}

/// Per-segment execution report of a coalesced row-batch.
///
/// Metric attribution: the batch's program replay is shared, so
/// `sim_cycles` and `control_bits` are the segment's occupancy-proportional
/// share of the batch totals. `switch_events` is *exact* — the per-row
/// switch counters attribute every memristor flip inside the segment's row
/// range to it (flips in unoccupied background rows belong to no job and
/// appear only in the aggregate bank metrics).
#[derive(Debug, Clone)]
pub struct SegmentReport {
    pub job: u64,
    /// Element offset within the owning job's result accumulator.
    pub offset: usize,
    /// Elements (rows) this segment occupied in the shared batch.
    pub span: usize,
    /// Per-segment values, or why this segment — alone — failed.
    pub values: std::result::Result<ChunkValues, String>,
    /// Occupancy-proportional share of the batch's simulated cycles.
    pub sim_cycles: u64,
    /// Occupancy-proportional share of the batch's control traffic.
    pub control_bits: u64,
    /// Exact switching energy inside this segment's row range.
    pub switch_events: u64,
    /// Rows of this segment's placement found stuck-at during the batch.
    /// Empty when the segment executed on healthy rows — and also when a
    /// loader error preempted execution (the loader error wins). A
    /// non-empty list makes the dispatcher quarantine the rows and remap
    /// the segment instead of failing the job.
    pub stuck_rows: Vec<usize>,
}

impl Payload {
    /// Pair up two element-wise operand vectors — the `submit(a, b)` payload.
    pub fn pairs(a: &[u64], b: &[u64]) -> Result<Payload> {
        ensure!(a.len() == b.len(), "operand vectors differ in length ({} vs {})", a.len(), b.len());
        Ok(Payload::Pairs(a.iter().copied().zip(b.iter().copied()).collect()))
    }

    /// Operand shape of this payload — the routing/compatibility key
    /// matched against [`WorkloadKind::shape`]. `None` for the poison
    /// fault hook, which is not a job.
    pub fn shape(&self) -> Option<JobShape> {
        match self {
            Payload::Pairs(_) => Some(JobShape::ElementWise),
            Payload::Rows(_) => Some(JobShape::RowVectors),
            Payload::States(_) => Some(JobShape::KeccakState),
            Payload::Poison => None,
        }
    }

    /// Split into per-chunk payloads of at most `rows` elements each — the
    /// client-side chunking step of `submit_job`.
    pub fn chunked(&self, rows: usize) -> Vec<Payload> {
        let rows = rows.max(1);
        match self {
            Payload::Pairs(p) => p.chunks(rows).map(|c| Payload::Pairs(c.to_vec())).collect(),
            Payload::Rows(r) => r.chunks(rows).map(|c| Payload::Rows(c.to_vec())).collect(),
            Payload::States(s) => s.chunks(rows).map(|c| Payload::States(c.to_vec())).collect(),
            Payload::Poison => vec![Payload::Poison],
        }
    }

    /// Elements this payload carries (rows for sort payloads).
    pub fn len(&self) -> usize {
        match self {
            Payload::Pairs(p) => p.len(),
            Payload::Rows(r) => r.len(),
            Payload::States(s) => s.len(),
            Payload::Poison => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Result values of one executed chunk, mirroring [`Payload`].
#[derive(Debug, Clone)]
pub enum ChunkValues {
    Scalars(Vec<u64>),
    Rows(Vec<Vec<u64>>),
    States(Vec<[u64; SHA3_LANES]>),
}

/// The operand loader / result reader for a compiled workload.
/// Opaque compiled-workload handle (loader/reader dispatch).
#[derive(Clone)]
pub enum Compiled {
    MultPim(MultPim),
    MultSerial(SerialMultiplier),
    Adder(Adder),
    AlignedAdder(AlignedAdder),
    Sorter(crate::algorithms::sort::Sorter),
    Sha3(Sha3Unit),
}

impl Compiled {
    fn load_pair(&self, state: &mut BitMatrix, row: usize, a: u64, b: u64) -> Result<()> {
        match self {
            Compiled::MultPim(m) => m.load(state, row, a, b),
            Compiled::MultSerial(m) => m.load(state, row, a, b),
            Compiled::Adder(m) => m.load(state, row, a, b),
            Compiled::AlignedAdder(m) => m.load(state, row, a, b),
            Compiled::Sorter(_) => bail!("sort workloads take per-row element vectors; use run_sort_batch"),
            Compiled::Sha3(_) => bail!("sha3 workloads take per-row keccak states; use a States payload"),
        }
    }

    fn read_result(&self, state: &BitMatrix, row: usize) -> Result<u64> {
        match self {
            Compiled::MultPim(m) => m.read_product(state, row),
            Compiled::MultSerial(m) => m.read_product(state, row),
            Compiled::Adder(m) => m.read_sum(state, row),
            Compiled::AlignedAdder(m) => m.read_sum(state, row),
            Compiled::Sorter(_) => bail!("sort workloads read element vectors; use run_sort_batch"),
            Compiled::Sha3(_) => bail!("sha3 workloads read keccak states; use a States payload"),
        }
    }
}

/// One crossbar plus its compiled program, prepared once for the wire
/// pipeline (the controller encodes *and periphery-decodes* a compiled
/// program a single time — shared process-wide via
/// [`prepared_workload_cached`] — and replays the trusted stream to every
/// batch; see DESIGN.md §Replay fast path).
pub struct Worker {
    pub crossbar: Crossbar,
    pub model: ModelKind,
    program: Program,
    prepared: PreparedProgram,
    compiled: Compiled,
    /// How batches replay the prepared program (the `ServiceConfig`
    /// `replay_mode` knob; default [`ReplayMode::Decoded`]).
    replay_mode: ReplayMode,
    /// Word-range executor threads per decoded replay.
    replay_threads: usize,
    /// Shared view of the bank's injected stuck-at faults
    /// (`PimService::inject_stuck`), synced into the crossbar at each batch
    /// boundary — faults appearing mid-batch take effect from the next one.
    fault_source: Option<Arc<Mutex<FaultMap>>>,
}

/// Build the workload program for `model` on `geom`, applying the paper's
/// Section 5 methodology: build the most permissive variant the model can
/// host, then legalize/pack for the model.
pub fn compile_workload(kind: WorkloadKind, model: ModelKind, geom: Geometry) -> Result<(Program, Compiled)> {
    match kind {
        WorkloadKind::Mul32 => match model {
            ModelKind::Baseline => {
                let m = build_serial_multiplier(geom, 32)?;
                Ok((m.program.clone(), Compiled::MultSerial(m)))
            }
            ModelKind::Minimal => {
                let m = build_multpim(geom, MultPimVariant::Plain)?;
                m.program.check_model(ModelKind::Minimal)?;
                Ok((m.program.clone(), Compiled::MultPim(m)))
            }
            ModelKind::Standard => {
                let m = build_multpim(geom, MultPimVariant::Fast)?;
                m.program.check_model(ModelKind::Standard)?;
                Ok((m.program.clone(), Compiled::MultPim(m)))
            }
            ModelKind::Unlimited => {
                let mut m = build_multpim(geom, MultPimVariant::Fast)?;
                let (packed, _) = pack_program(&m.program.ops, ModelKind::Unlimited, &geom, GateSet::NotNor);
                m.program.ops = packed;
                Ok((m.program.clone(), Compiled::MultPim(m)))
            }
        },
        WorkloadKind::Sort16 => {
            if model == ModelKind::Baseline {
                let s = crate::algorithms::sort::build_sorter_serial(geom, SORT_ELEMS, SORT_BITS)?;
                return Ok((s.program.clone(), Compiled::Sorter(s)));
            }
            let s = crate::algorithms::sort::build_sorter_partitioned(geom, SORT_BITS)?;
            // The bitonic network mixes intra indices across ascending /
            // descending compare-exchange pairs: legalize for the stricter
            // models, pack for unlimited (Section 5 methodology).
            let prog = match model {
                ModelKind::Unlimited => {
                    let (packed, _) = pack_program(&s.program.ops, ModelKind::Unlimited, &geom, GateSet::NotNor);
                    Program { ops: packed, ..s.program.clone() }
                }
                _ => {
                    let (legal, _) = s.program.legalize(model, &crate::isa::lower::LegalizeConfig::default())?;
                    legal
                }
            };
            Ok((prog, Compiled::Sorter(s)))
        }
        WorkloadKind::Add32 => {
            if model == ModelKind::Baseline {
                let a = build_adder(geom, 32)?;
                return Ok((a.program.clone(), Compiled::Adder(a)));
            }
            // Partitioned crossbars need the partition-aligned mapping
            // (No Split-Input, footnote 3); pack what the model allows.
            let a = build_adder_aligned(geom, 32)?;
            let mut prog = a.program.clone();
            let (packed, _) = pack_program(&prog.ops, model, &geom, GateSet::NotNor);
            prog.ops = packed;
            Ok((prog, Compiled::AlignedAdder(a)))
        }
        WorkloadKind::Sha3 => {
            // The round builder already emits class-homogeneous cycles legal
            // under Minimal (and so under every partitioned model) — see
            // algorithms::sha3. The baseline serializes via the legalizer.
            // Never `pack_program` this workload: packing could merge cycles
            // of different gate classes, and a mixed-class cycle has no wire
            // encoding (the per-cycle gate-type field is shared).
            let unit = build_keccak_f(geom)?;
            let prog = match model {
                ModelKind::Baseline => {
                    let (legal, _) =
                        unit.program.legalize(ModelKind::Baseline, &crate::isa::lower::LegalizeConfig::default())?;
                    legal
                }
                _ => {
                    unit.program.check_model(model)?;
                    unit.program.clone()
                }
            };
            Ok((prog, Compiled::Sha3(unit)))
        }
    }
}

/// Process-wide compile cache. Workload compilation — including the sort
/// network's legalization, previously re-run by every worker on the hot
/// path — is deterministic in `(kind, model, geom)`, so every worker (and
/// every re-spawned replacement after a panic) reuses one compiled program.
/// Each entry is statically verified on first use
/// ([`verify::verify_program`]); a workload whose program carries an
/// error-severity diagnostic never reaches any worker.
pub fn compile_workload_cached(kind: WorkloadKind, model: ModelKind, geom: Geometry) -> Result<(Program, Compiled)> {
    let (program, compiled, _) = prepared_workload_cached(kind, model, geom)?;
    Ok((program, compiled))
}

/// The full process-wide workload cache: the compiled program, its
/// loader/reader handle, *and* the wire-prepared [`PreparedProgram`]
/// carrying the decode-once trusted op cache. Sharing the prepared program
/// per `(kind, model, geometry)` means the whole bank — and every respawned
/// worker after a fault — encodes and periphery-decodes each workload
/// exactly once, then replays the trusted stream for every batch
/// (DESIGN.md §Replay fast path).
pub fn prepared_workload_cached(
    kind: WorkloadKind,
    model: ModelKind,
    geom: Geometry,
) -> Result<(Program, Compiled, PreparedProgram)> {
    type Entry = (Program, Compiled, PreparedProgram);
    type Cache = Mutex<HashMap<(WorkloadKind, ModelKind, Geometry), Entry>>;
    static CACHE: OnceLock<Cache> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    // Workers run on panic-prone threads (fault injection kills them
    // mid-operation); recover the map instead of poisoning every future
    // compile.
    let mut map = cache.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(entry) = map.get(&(kind, model, geom)) {
        return Ok(entry.clone());
    }
    let (program, compiled) = compile_workload(kind, model, geom)?;
    verify::verify_program(&program, model).ensure_clean()?;
    // Prepare (encode + decode once) on a scratch crossbar: preparation is
    // controller-side and touches no cells, so the scratch state is
    // irrelevant and the cached stream is valid on any same-geometry bank.
    let mut scratch = Crossbar::new(geom, kind.gate_set());
    let prepared = program.prepare(&mut ExecPipeline::wire(model, &mut scratch))?;
    let entry = (program, compiled, prepared);
    map.insert((kind, model, geom), entry.clone());
    Ok(entry)
}

impl Worker {
    pub fn new(kind: WorkloadKind, model: ModelKind, geom: Geometry) -> Result<Self> {
        let (program, compiled, prepared) = prepared_workload_cached(kind, model, geom)?;
        let mut crossbar = Crossbar::new(geom, kind.gate_set());
        // Coalesced batches charge each segment its exact row-range
        // switching energy, so the worker's crossbar always attributes
        // switches per row.
        crossbar.enable_row_switch_tracking();
        Ok(Self {
            crossbar,
            model,
            program,
            prepared,
            compiled,
            replay_mode: ReplayMode::Decoded,
            replay_threads: 1,
            fault_source: None,
        })
    }

    /// Configure how this worker replays the prepared program per batch
    /// (plumbed from `ServiceConfig::replay_mode` / `replay_threads`).
    pub fn set_replay(&mut self, mode: ReplayMode, threads: usize) {
        self.replay_mode = mode;
        self.replay_threads = threads.max(1);
    }

    /// Attach the bank-shared stuck-at fault map. The worker re-reads it at
    /// every batch boundary, so `PimService::inject_stuck` takes effect on
    /// the next batch without restarting anything.
    pub fn set_fault_source(&mut self, source: Arc<Mutex<FaultMap>>) {
        self.fault_source = Some(source);
    }

    /// Geometry this worker serves.
    pub fn geom(&self) -> Geometry {
        self.crossbar.geom
    }

    /// Per-batch latency in simulated cycles.
    pub fn batch_cycles(&self) -> usize {
        self.program.stats().cycles
    }

    /// Replay the prepared program once (decoded fast path by default) and
    /// fold the pipeline-metered control traffic into the batch delta.
    fn run_prepared_batch(&mut self, before: Metrics) -> Result<Metrics> {
        let mut pipe = ExecPipeline::wire(self.model, &mut self.crossbar);
        pipe.set_replay_mode(self.replay_mode);
        pipe.set_replay_threads(self.replay_threads);
        pipe.run_prepared(&self.prepared)?;
        let wire = pipe.stats();
        let mut delta = self.crossbar.metrics.delta_since(&before);
        delta.control_bits += wire.control_bits;
        delta.messages += wire.messages;
        Ok(delta)
    }

    /// Execute one row-batch of element pairs end-to-end through the
    /// message path; returns the per-element results and the metrics delta.
    ///
    /// Convenience wrapper over [`Worker::run_segments`] with a single
    /// anonymous segment, so the batch hygiene (row clearing — the
    /// ghost-row fix) lives in exactly one place.
    pub fn run_batch(&mut self, pairs: &[(u64, u64)]) -> Result<(Vec<u64>, Metrics)> {
        let seg = Segment { job: 0, offset: 0, payload: Payload::Pairs(pairs.to_vec()), remaps: 0 };
        let (reports, delta) = self.run_segments(std::slice::from_ref(&seg))?;
        let report = reports.into_iter().next().expect("one segment yields one report");
        match report.values.map_err(|e| anyhow!(e))? {
            ChunkValues::Scalars(v) => Ok((v, delta)),
            ChunkValues::Rows(_) => unreachable!("pair payloads read back as scalars"),
        }
    }

    /// Execute one coalesced row-batch — segments from any number of jobs
    /// packed back-to-back into the shared row dimension — end-to-end: the
    /// single entry point the scheduler's worker threads use.
    ///
    /// Failure domains: a loader or readback error fails only its own
    /// segment (`values: Err` in that segment's report; co-batched segments
    /// still complete). An `Err` return fails the whole batch (occupancy
    /// overflow, pipeline fault). Only a genuine panic — a simulated
    /// hardware fault — takes the worker down.
    pub fn run_segments(&mut self, segments: &[Segment]) -> Result<(Vec<SegmentReport>, Metrics)> {
        let mut plan: Vec<Vec<usize>> = Vec::with_capacity(segments.len());
        let mut base = 0usize;
        for seg in segments {
            plan.push((base..base + seg.payload.len()).collect());
            base += seg.payload.len();
        }
        let (reports, _row_wear, delta) = self.run_segments_placed(segments, &plan)?;
        Ok((reports, delta))
    }

    /// [`Worker::run_segments`] with an explicit row placement: `plan[i]`
    /// lists the rows segment `i` occupies (the dispatcher computes it via
    /// `WearMap::assign_rows` — coldest healthy rows under wear leveling,
    /// front-packed otherwise). Column gates never cross rows and every
    /// batch starts from cleared rows, so a segment's values and exact
    /// switch attribution are invariant under placement.
    ///
    /// Reliability hooks: the bank-shared fault map is synced at the batch
    /// boundary and its stuck cells forced after operand load (faults
    /// corrupt inputs) and after replay (faults corrupt outputs); a segment
    /// placed on a stuck row reports `stuck_rows` so the dispatcher can
    /// quarantine and remap it. The batch's per-row switch snapshot is
    /// folded into the crossbar's persistent [`crate::crossbar::WearMap`]
    /// and returned alongside the reports.
    pub fn run_segments_placed(&mut self, segments: &[Segment], plan: &[Vec<usize>]) -> Result<(Vec<SegmentReport>, Vec<u64>, Metrics)> {
        let rows = self.crossbar.geom.rows;
        let occupied: usize = segments.iter().map(|s| s.payload.len()).sum();
        if occupied > rows {
            bail!("coalesced batch of {occupied} elements exceeds {rows} rows");
        }
        ensure!(plan.len() == segments.len(), "placement plan covers {} of {} segments", plan.len(), segments.len());
        let mut used = vec![false; rows];
        for (seg, assigned) in segments.iter().zip(plan) {
            ensure!(
                assigned.len() == seg.payload.len(),
                "segment of {} elements placed on {} rows",
                seg.payload.len(),
                assigned.len()
            );
            for &r in assigned {
                ensure!(r < rows, "placement row {r} outside the {rows}-row bank");
                ensure!(!used[r], "placement row {r} assigned twice");
                used[r] = true;
            }
        }
        // Sync this batch's fault view: stuck cells injected mid-batch take
        // effect from the next batch boundary.
        if let Some(source) = &self.fault_source {
            let faults = source.lock().unwrap_or_else(|e| e.into_inner()).clone();
            self.crossbar.set_faults(faults);
        }
        // Batch hygiene (the structural ghost-row fix): every batch starts
        // from fully cleared rows, so no job's values or metrics can depend
        // on what the bank ran before it.
        self.crossbar.state.clear_rows(0, rows)?;
        self.crossbar.reset_row_switches();
        let before = self.crossbar.metrics;
        let mut load_errs: Vec<Option<String>> = Vec::with_capacity(segments.len());
        for (seg, assigned) in segments.iter().zip(plan) {
            load_errs.push(self.load_segment(seg, assigned).err().map(|e| format!("{e:#}")));
        }
        // Stuck devices override whatever the operand writes produced...
        self.crossbar.apply_faults()?;
        // If no segment loaded, the shared replay would compute garbage for
        // nobody: skip it and charge nothing (a batch with zero cycles is
        // reported as not executed).
        let delta = if load_errs.iter().all(Option::is_some) {
            Metrics::default()
        } else {
            let delta = self.run_prepared_batch(before)?;
            // ... and whatever the gates computed afterwards. Both passes
            // write through `BitMatrix::set`, so healthy segments' metrics
            // are untouched.
            self.crossbar.apply_faults()?;
            delta
        };
        // Wear is physical: fold this batch's exact per-row switch counts
        // into the persistent map before anything resets them.
        let row_wear = self.crossbar.absorb_wear();
        let stuck = self.crossbar.stuck_rows();
        let mut reports = Vec::with_capacity(segments.len());
        for (i, seg) in segments.iter().enumerate() {
            let span = seg.payload.len();
            let seg_stuck: Vec<usize> = plan[i].iter().copied().filter(|r| stuck.binary_search(r).is_ok()).collect();
            let values = match (&load_errs[i], seg_stuck.is_empty()) {
                (Some(e), _) => Err(e.clone()),
                (None, false) => Err(format!("stuck-at fault on row(s) {seg_stuck:?}")),
                (None, true) => self.read_segment(seg, &plan[i]).map_err(|e| format!("{e:#}")),
            };
            let stuck_rows = if load_errs[i].is_some() { Vec::new() } else { seg_stuck };
            reports.push(SegmentReport {
                job: seg.job,
                offset: seg.offset,
                span,
                values,
                sim_cycles: delta.cycles * span as u64 / occupied.max(1) as u64,
                control_bits: delta.control_bits * span as u64 / occupied.max(1) as u64,
                switch_events: self.crossbar.row_switches_at(&plan[i]),
                stuck_rows,
            });
        }
        Ok((reports, row_wear, delta))
    }

    /// Load one segment's operands onto its assigned rows. A malformed
    /// operand fails only this segment; rows already written stay loaded
    /// (they execute as garbage in this segment's own rows and are never
    /// read back).
    fn load_segment(&mut self, seg: &Segment, assigned: &[usize]) -> Result<()> {
        match &seg.payload {
            Payload::Pairs(pairs) => {
                for (&row, &(a, b)) in assigned.iter().zip(pairs) {
                    self.compiled.load_pair(&mut self.crossbar.state, row, a, b)?;
                }
                Ok(())
            }
            Payload::Rows(rows_data) => {
                let Compiled::Sorter(sorter) = &self.compiled else {
                    bail!("per-row sort payload on a non-sort workload");
                };
                for (&row, vals) in assigned.iter().zip(rows_data) {
                    sorter.load(&mut self.crossbar.state, row, vals)?;
                }
                Ok(())
            }
            Payload::States(states) => {
                let Compiled::Sha3(unit) = &self.compiled else {
                    bail!("keccak state payload on a non-sha3 workload");
                };
                for (&row, st) in assigned.iter().zip(states) {
                    unit.load(&mut self.crossbar.state, row, st)?;
                }
                Ok(())
            }
            Payload::Poison => panic!("injected crossbar fault"),
        }
    }

    /// Read one segment's results back from its assigned rows.
    fn read_segment(&self, seg: &Segment, assigned: &[usize]) -> Result<ChunkValues> {
        match &seg.payload {
            Payload::Pairs(pairs) => {
                let mut out = Vec::with_capacity(pairs.len());
                for &row in assigned.iter().take(pairs.len()) {
                    out.push(self.compiled.read_result(&self.crossbar.state, row)?);
                }
                Ok(ChunkValues::Scalars(out))
            }
            Payload::Rows(rows_data) => {
                let Compiled::Sorter(sorter) = &self.compiled else {
                    bail!("per-row sort payload on a non-sort workload");
                };
                let mut out = Vec::with_capacity(rows_data.len());
                for &row in assigned.iter().take(rows_data.len()) {
                    out.push(sorter.read(&self.crossbar.state, row)?);
                }
                Ok(ChunkValues::Rows(out))
            }
            Payload::States(states) => {
                let Compiled::Sha3(unit) = &self.compiled else {
                    bail!("keccak state payload on a non-sha3 workload");
                };
                let mut out = Vec::with_capacity(states.len());
                for &row in assigned.iter().take(states.len()) {
                    out.push(unit.read(&self.crossbar.state, row)?);
                }
                Ok(ChunkValues::States(out))
            }
            Payload::Poison => bail!("poison payload has no results"),
        }
    }

    /// Execute one row-batch of sort jobs (one 16-element vector per row).
    /// Like [`Worker::run_batch`], a single-segment wrapper over
    /// [`Worker::run_segments`].
    pub fn run_sort_batch(&mut self, rows_data: &[Vec<u64>]) -> Result<(Vec<Vec<u64>>, Metrics)> {
        let seg = Segment { job: 0, offset: 0, payload: Payload::Rows(rows_data.to_vec()), remaps: 0 };
        let (reports, delta) = self.run_segments(std::slice::from_ref(&seg))?;
        let report = reports.into_iter().next().expect("one segment yields one report");
        match report.values.map_err(|e| anyhow!(e))? {
            ChunkValues::Rows(v) => Ok((v, delta)),
            _ => unreachable!("row payloads read back as rows"),
        }
    }

    /// Execute one row-batch of Keccak-f[1600] permutations (one 25-lane
    /// state per row). Single-segment wrapper over [`Worker::run_segments`].
    pub fn run_sha3_batch(&mut self, states: &[[u64; SHA3_LANES]]) -> Result<(Vec<[u64; SHA3_LANES]>, Metrics)> {
        let seg = Segment { job: 0, offset: 0, payload: Payload::States(states.to_vec()), remaps: 0 };
        let (reports, delta) = self.run_segments(std::slice::from_ref(&seg))?;
        let report = reports.into_iter().next().expect("one segment yields one report");
        match report.values.map_err(|e| anyhow!(e))? {
            ChunkValues::States(v) => Ok((v, delta)),
            _ => unreachable!("state payloads read back as states"),
        }
    }
}

/// Choose the geometry a workload/model combination needs. Fallible: the
/// row count comes from user configuration, and hiding the validation
/// behind an `expect` turned a bad `rows` into a panic instead of a clean
/// service-start error.
pub fn workload_geometry(kind: WorkloadKind, model: ModelKind, rows: usize) -> Result<Geometry> {
    match (kind, model) {
        // SHA-3 keeps its z-bit-slice geometry (k=64, one partition per lane
        // bit) on every model — the baseline serializes in the legalizer,
        // not by dropping partitions, so loads/reads use one layout.
        (WorkloadKind::Sha3, _) => Geometry::new(4096, 64, rows),
        // Serial baselines run on a partition-free crossbar.
        (_, ModelKind::Baseline) => Geometry::new(1024, 1, rows),
        // MultPIM at paper scale: n=1024, k=32 (one partition per bit).
        (WorkloadKind::Mul32, _) => Geometry::paper(rows),
        (WorkloadKind::Add32, _) => Geometry::new(1024, 32, rows),
        // One element per partition: 16 partitions.
        (WorkloadKind::Sort16, _) => Geometry::new(512, SORT_ELEMS, rows),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_multiplies_batches() {
        for model in [ModelKind::Baseline, ModelKind::Minimal, ModelKind::Standard, ModelKind::Unlimited] {
            let geom = workload_geometry(WorkloadKind::Mul32, model, 16).unwrap();
            let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
            let pairs: Vec<(u64, u64)> = (0..16).map(|i| (0xabcd1234 ^ (i * 77), 0x1357 + i * 991)).collect();
            let (out, metrics) = w.run_batch(&pairs).unwrap();
            for (i, &(a, b)) in pairs.iter().enumerate() {
                assert_eq!(out[i], a * b, "{}*{} under {}", a, b, model.name());
            }
            assert!(metrics.cycles > 0 && metrics.control_bits > 0);
        }
    }

    #[test]
    fn worker_permutes_keccak_states() {
        use crate::algorithms::sha3;
        for model in [ModelKind::Minimal, ModelKind::Standard] {
            let geom = workload_geometry(WorkloadKind::Sha3, model, 4).unwrap();
            let mut w = Worker::new(WorkloadKind::Sha3, model, geom).unwrap();
            let states: Vec<[u64; 25]> = (0..4)
                .map(|r| {
                    let mut st = [0u64; 25];
                    for (i, lane) in st.iter_mut().enumerate() {
                        *lane = (r as u64 + 1).wrapping_mul(0x9e3779b97f4a7c15).rotate_left(i as u32) ^ i as u64;
                    }
                    st
                })
                .collect();
            let (out, metrics) = w.run_sha3_batch(&states).unwrap();
            for (r, st) in states.iter().enumerate() {
                let mut want = *st;
                sha3::keccak_f_sw(&mut want);
                assert_eq!(out[r], want, "row {r} under {}", model.name());
            }
            // 24 rounds, each within the published 3,494-cycle budget.
            assert!(metrics.cycles <= (sha3::ROUNDS * sha3::PUBLISHED_ROUND_CYCLES) as u64);
        }
    }

    #[test]
    fn worker_adds_batches() {
        let geom = workload_geometry(WorkloadKind::Add32, ModelKind::Minimal, 8).unwrap();
        let mut w = Worker::new(WorkloadKind::Add32, ModelKind::Minimal, geom).unwrap();
        let pairs: Vec<(u64, u64)> = (0..8).map(|i| (0xffff_ffff - i, i * 3)).collect();
        let (out, _) = w.run_batch(&pairs).unwrap();
        for (i, &(a, b)) in pairs.iter().enumerate() {
            assert_eq!(out[i], a + b);
        }
    }

    /// The model hierarchy must order latency: unlimited <= standard <= minimal,
    /// all far below the serial baseline (Figure 6(a) shape).
    #[test]
    fn model_latency_ordering() {
        let cycles = |model: ModelKind| {
            let geom = workload_geometry(WorkloadKind::Mul32, model, 1).unwrap();
            Worker::new(WorkloadKind::Mul32, model, geom).unwrap().batch_cycles()
        };
        let (base, unl, std_, min) = (
            cycles(ModelKind::Baseline),
            cycles(ModelKind::Unlimited),
            cycles(ModelKind::Standard),
            cycles(ModelKind::Minimal),
        );
        assert!(unl <= std_ && std_ <= min, "unl={unl} std={std_} min={min}");
        assert!(base > 5 * min, "serial baseline {base} must dwarf partitioned {min}");
    }

    /// Regression (the ghost-row bug): re-running a smaller batch on a bank
    /// that previously served a larger one used to leave stale operands in
    /// the tail rows, so `switch_events` depended on bank history. After
    /// the fix the same batch reports identical values *and* metrics no
    /// matter what ran before it.
    #[test]
    fn rerun_on_dirty_bank_is_deterministic() {
        let model = ModelKind::Minimal;
        let geom = workload_geometry(WorkloadKind::Mul32, model, 8).unwrap();
        let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        // Pollute all 8 rows, then serve a 2-row batch twice.
        let big: Vec<(u64, u64)> = (0..8).map(|i| (0xdead_0000 + i, 0xbeef_0000 + i)).collect();
        w.run_batch(&big).unwrap();
        let small = [(12345u64, 67890u64), (777u64, 999u64)];
        let (v1, m1) = w.run_batch(&small).unwrap();
        let (v2, m2) = w.run_batch(&small).unwrap();
        assert_eq!(v1, v2);
        assert_eq!(m1, m2, "per-batch metrics must not depend on bank history");
        assert!(m1.switch_events > 0);

        // And against a pristine worker: bit-identical metrics too.
        let mut fresh = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        let (v3, m3) = fresh.run_batch(&small).unwrap();
        assert_eq!(v1, v3);
        assert_eq!(m1, m3, "used bank must match a pristine bank exactly");
    }

    /// A coalesced batch shares one program replay: proportional cycle
    /// shares, exact row-range switch attribution, per-segment values.
    #[test]
    fn run_segments_packs_jobs_and_attributes_metrics() {
        let model = ModelKind::Minimal;
        let geom = workload_geometry(WorkloadKind::Mul32, model, 8).unwrap();
        let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        let seg = |job: u64, offset: usize, pairs: Vec<(u64, u64)>| Segment { job, offset, payload: Payload::Pairs(pairs), remaps: 0 };
        let segments = vec![
            seg(7, 0, vec![(3, 5), (11, 13)]),
            seg(9, 4, vec![(100, 200)]),
            seg(12, 0, vec![(1 << 20, 1 << 11), (6, 7), (8, 9)]),
        ];
        let (reports, delta) = w.run_segments(&segments).unwrap();
        assert_eq!(reports.len(), 3);
        let expect: [&[u64]; 3] = [&[15, 143], &[20000], &[1 << 31, 42, 72]];
        for (i, r) in reports.iter().enumerate() {
            let ChunkValues::Scalars(vals) = r.values.as_ref().unwrap() else { panic!("scalar workload") };
            assert_eq!(vals.as_slice(), expect[i], "segment {i}");
        }
        // Proportional shares: 2/6, 1/6, 3/6 of the batch cycles.
        assert_eq!(reports[0].sim_cycles, delta.cycles * 2 / 6);
        assert_eq!(reports[1].sim_cycles, delta.cycles / 6);
        assert_eq!(reports[2].sim_cycles, delta.cycles * 3 / 6);
        // Exact switch attribution: segment counts can never exceed the
        // batch total (background rows absorb the remainder).
        let attributed: u64 = reports.iter().map(|r| r.switch_events).sum();
        assert!(attributed <= delta.switch_events);
        assert!(reports.iter().all(|r| r.switch_events > 0));
    }

    /// A batch whose occupancy exceeds the row count is a scheduler bug and
    /// fails as a unit.
    #[test]
    fn run_segments_rejects_overfull_batch() {
        let model = ModelKind::Minimal;
        let geom = workload_geometry(WorkloadKind::Mul32, model, 2).unwrap();
        let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        let segments = vec![
            Segment { job: 1, offset: 0, payload: Payload::Pairs(vec![(1, 2), (3, 4)]), remaps: 0 },
            Segment { job: 2, offset: 0, payload: Payload::Pairs(vec![(5, 6)]), remaps: 0 },
        ];
        assert!(w.run_segments(&segments).is_err());
    }

    /// Scattered placement (the wear-leveling / remap path) must reproduce
    /// front-packed values and exact switch attribution bit-for-bit: gates
    /// never cross rows and every batch starts cleared, so per-row behaviour
    /// depends only on that row's loaded data.
    #[test]
    fn placed_execution_is_placement_invariant() {
        let model = ModelKind::Minimal;
        let geom = workload_geometry(WorkloadKind::Mul32, model, 8).unwrap();
        let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        let segments = vec![
            Segment { job: 1, offset: 0, payload: Payload::Pairs(vec![(3, 5), (11, 13)]), remaps: 0 },
            Segment { job: 2, offset: 0, payload: Payload::Pairs(vec![(100, 200)]), remaps: 0 },
        ];
        let (front, _) = w.run_segments(&segments).unwrap();
        let plan = vec![vec![5, 7], vec![2]];
        let (scattered, row_wear, _) = w.run_segments_placed(&segments, &plan).unwrap();
        for (a, b) in front.iter().zip(&scattered) {
            let (ChunkValues::Scalars(va), ChunkValues::Scalars(vb)) = (a.values.as_ref().unwrap(), b.values.as_ref().unwrap())
            else {
                panic!("scalar workload")
            };
            assert_eq!(va, vb, "values are placement-invariant");
            assert_eq!(a.switch_events, b.switch_events, "switch attribution is placement-invariant");
        }
        assert_eq!(row_wear.len(), 8);
        assert!(row_wear[5] > 0 && row_wear[2] > 0);
        // Wear persisted across both batches.
        assert!(w.crossbar.wear().total_wear() > 0);
        // Malformed plans are scheduler bugs and fail the batch as a unit.
        assert!(w.run_segments_placed(&segments, &[vec![0, 1], vec![0]]).is_err(), "duplicate row");
        assert!(w.run_segments_placed(&segments, &[vec![0, 99], vec![1]]).is_err(), "row out of range");
        assert!(w.run_segments_placed(&segments, &[vec![0], vec![1]]).is_err(), "span mismatch");
    }

    /// A stuck cell surfaces as a per-segment `stuck_rows` report — the
    /// dispatcher's quarantine trigger — while co-batched segments on
    /// healthy rows still complete with correct values.
    #[test]
    fn stuck_row_reported_without_failing_cobatched_segments() {
        let model = ModelKind::Minimal;
        let geom = workload_geometry(WorkloadKind::Mul32, model, 4).unwrap();
        let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        w.set_fault_source(Arc::new(Mutex::new(FaultMap::new().stuck(1, 0, true))));
        let segments = vec![
            Segment { job: 1, offset: 0, payload: Payload::Pairs(vec![(3, 5), (7, 9)]), remaps: 0 },
            Segment { job: 2, offset: 0, payload: Payload::Pairs(vec![(11, 13)]), remaps: 0 },
        ];
        let (reports, _, _) = w.run_segments_placed(&segments, &[vec![0, 1], vec![2]]).unwrap();
        assert_eq!(reports[0].stuck_rows, vec![1]);
        assert!(reports[0].values.is_err());
        assert!(reports[1].stuck_rows.is_empty());
        let ChunkValues::Scalars(v) = reports[1].values.as_ref().unwrap() else { panic!("scalar workload") };
        assert_eq!(v.as_slice(), &[143]);
    }

    /// The per-batch metrics delta must charge exactly the wire format's
    /// control bits per gate cycle plus one write command per init cycle.
    #[test]
    fn batch_delta_meters_control_exactly() {
        let model = ModelKind::Minimal;
        let geom = workload_geometry(WorkloadKind::Mul32, model, 4).unwrap();
        let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        let pairs: Vec<(u64, u64)> = (0..4).map(|i| (i + 1, 3 * i + 2)).collect();
        let (_, m) = w.run_batch(&pairs).unwrap();
        let gate_msg = crate::isa::encode::message_bits(model, &geom) as u64;
        let init_msg = crate::crossbar::crossbar::init_message_bits(&geom) as u64;
        assert_eq!(m.control_bits, m.gate_cycles * gate_msg + m.init_cycles * init_msg);
        assert_eq!(m.messages, m.cycles);
    }
}
