//! Crossbar worker: owns one simulated crossbar plus the compiled program
//! for its workload, and executes row-batches end-to-end through the
//! production control pipeline (encode → periphery decode → execute).

use crate::algorithms::addition::{build_adder, build_adder_aligned, Adder, AlignedAdder};
use crate::algorithms::mult_serial::{build_serial_multiplier, SerialMultiplier};
use crate::algorithms::multpim::{build_multpim, MultPim, MultPimVariant};
use crate::algorithms::program::Program;
use crate::backend::{ExecPipeline, PreparedProgram};
use crate::crossbar::crossbar::{Crossbar, Metrics};
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::models::ModelKind;
use crate::isa::schedule::pack_program;
use anyhow::{bail, Result};

/// Which vectored operation this service instance executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Element-wise 32-bit multiply via the partitioned MultPIM program
    /// (or the serial baseline when the model is `Baseline`).
    Mul32,
    /// Element-wise 32-bit add (serial single-row ripple adder).
    Add32,
    /// Per-row sort of 16 six-bit elements (partitioned bitonic network;
    /// serial network on the baseline).
    Sort16,
}

/// Elements a sort job handles per row.
pub const SORT_ELEMS: usize = 16;
/// Element width of the sort workload.
pub const SORT_BITS: usize = 6;

/// A chunk's operand payload: scalar pairs for element-wise arithmetic,
/// per-row element vectors for sort jobs.
#[derive(Debug, Clone)]
pub enum Payload {
    Pairs(Vec<(u64, u64)>),
    Rows(Vec<Vec<u64>>),
    /// Fault injection: executing this payload panics the worker thread,
    /// simulating a crossbar that dies mid-operation (used by the
    /// scheduler's resilience tests and `PimService::inject_worker_panic`).
    #[doc(hidden)]
    Poison,
}

impl Payload {
    /// Elements this payload carries (rows for sort payloads).
    pub fn len(&self) -> usize {
        match self {
            Payload::Pairs(p) => p.len(),
            Payload::Rows(r) => r.len(),
            Payload::Poison => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Result values of one executed chunk, mirroring [`Payload`].
#[derive(Debug, Clone)]
pub enum ChunkValues {
    Scalars(Vec<u64>),
    Rows(Vec<Vec<u64>>),
}

/// The operand loader / result reader for a compiled workload.
/// Opaque compiled-workload handle (loader/reader dispatch).
pub enum Compiled {
    MultPim(MultPim),
    MultSerial(SerialMultiplier),
    Adder(Adder),
    AlignedAdder(AlignedAdder),
    Sorter(crate::algorithms::sort::Sorter),
}

impl Compiled {
    fn load_pair(&self, state: &mut BitMatrix, row: usize, a: u64, b: u64) -> Result<()> {
        match self {
            Compiled::MultPim(m) => m.load(state, row, a, b),
            Compiled::MultSerial(m) => m.load(state, row, a, b),
            Compiled::Adder(m) => m.load(state, row, a, b),
            Compiled::AlignedAdder(m) => m.load(state, row, a, b),
            Compiled::Sorter(_) => bail!("sort workloads take per-row element vectors; use run_sort_batch"),
        }
    }

    fn read_result(&self, state: &BitMatrix, row: usize) -> Result<u64> {
        match self {
            Compiled::MultPim(m) => m.read_product(state, row),
            Compiled::MultSerial(m) => m.read_product(state, row),
            Compiled::Adder(m) => m.read_sum(state, row),
            Compiled::AlignedAdder(m) => m.read_sum(state, row),
            Compiled::Sorter(_) => bail!("sort workloads read element vectors; use run_sort_batch"),
        }
    }
}

/// One crossbar plus its compiled program, prepared once for the wire
/// pipeline (the controller encodes a compiled program a single time and
/// streams it to every batch — see DESIGN.md §Perf).
pub struct Worker {
    pub crossbar: Crossbar,
    pub model: ModelKind,
    program: Program,
    prepared: PreparedProgram,
    compiled: Compiled,
}

/// Build the workload program for `model` on `geom`, applying the paper's
/// Section 5 methodology: build the most permissive variant the model can
/// host, then legalize/pack for the model.
pub fn compile_workload(kind: WorkloadKind, model: ModelKind, geom: Geometry) -> Result<(Program, Compiled)> {
    match kind {
        WorkloadKind::Mul32 => match model {
            ModelKind::Baseline => {
                let m = build_serial_multiplier(geom, 32)?;
                Ok((m.program.clone(), Compiled::MultSerial(m)))
            }
            ModelKind::Minimal => {
                let m = build_multpim(geom, MultPimVariant::Plain)?;
                m.program.check_model(ModelKind::Minimal)?;
                Ok((m.program.clone(), Compiled::MultPim(m)))
            }
            ModelKind::Standard => {
                let m = build_multpim(geom, MultPimVariant::Fast)?;
                m.program.check_model(ModelKind::Standard)?;
                Ok((m.program.clone(), Compiled::MultPim(m)))
            }
            ModelKind::Unlimited => {
                let mut m = build_multpim(geom, MultPimVariant::Fast)?;
                let (packed, _) = pack_program(&m.program.ops, ModelKind::Unlimited, &geom, GateSet::NotNor);
                m.program.ops = packed;
                Ok((m.program.clone(), Compiled::MultPim(m)))
            }
        },
        WorkloadKind::Sort16 => {
            if model == ModelKind::Baseline {
                let s = crate::algorithms::sort::build_sorter_serial(geom, SORT_ELEMS, SORT_BITS)?;
                return Ok((s.program.clone(), Compiled::Sorter(s)));
            }
            let s = crate::algorithms::sort::build_sorter_partitioned(geom, SORT_BITS)?;
            // The bitonic network mixes intra indices across ascending /
            // descending compare-exchange pairs: legalize for the stricter
            // models, pack for unlimited (Section 5 methodology).
            let prog = match model {
                ModelKind::Unlimited => {
                    let (packed, _) = pack_program(&s.program.ops, ModelKind::Unlimited, &geom, GateSet::NotNor);
                    Program { ops: packed, ..s.program.clone() }
                }
                _ => {
                    let (legal, _) = s.program.legalize(model, &crate::isa::lower::LegalizeConfig::default())?;
                    legal
                }
            };
            Ok((prog, Compiled::Sorter(s)))
        }
        WorkloadKind::Add32 => {
            if model == ModelKind::Baseline {
                let a = build_adder(geom, 32)?;
                return Ok((a.program.clone(), Compiled::Adder(a)));
            }
            // Partitioned crossbars need the partition-aligned mapping
            // (No Split-Input, footnote 3); pack what the model allows.
            let a = build_adder_aligned(geom, 32)?;
            let mut prog = a.program.clone();
            let (packed, _) = pack_program(&prog.ops, model, &geom, GateSet::NotNor);
            prog.ops = packed;
            Ok((prog, Compiled::AlignedAdder(a)))
        }
    }
}

impl Worker {
    pub fn new(kind: WorkloadKind, model: ModelKind, geom: Geometry) -> Result<Self> {
        let (program, compiled) = compile_workload(kind, model, geom)?;
        let mut crossbar = Crossbar::new(geom, GateSet::NotNor);
        let prepared = program.prepare(&mut ExecPipeline::wire(model, &mut crossbar))?;
        Ok(Self { crossbar, model, program, prepared, compiled })
    }

    /// Geometry this worker serves.
    pub fn geom(&self) -> Geometry {
        self.crossbar.geom
    }

    /// Per-batch latency in simulated cycles.
    pub fn batch_cycles(&self) -> usize {
        self.program.stats().cycles
    }

    /// Stream the prepared program through the wire pipeline once and fold
    /// the pipeline-metered control traffic into the batch delta.
    fn run_prepared_batch(&mut self, before: Metrics) -> Result<Metrics> {
        let mut pipe = ExecPipeline::wire(self.model, &mut self.crossbar);
        pipe.run_prepared(&self.prepared)?;
        let wire = pipe.stats();
        let mut delta = self.crossbar.metrics.delta_since(&before);
        delta.control_bits += wire.control_bits;
        delta.messages += wire.messages;
        Ok(delta)
    }

    /// Execute one row-batch of element pairs end-to-end through the
    /// message path; returns the per-element results and the metrics delta.
    pub fn run_batch(&mut self, pairs: &[(u64, u64)]) -> Result<(Vec<u64>, Metrics)> {
        let rows = self.crossbar.geom.rows;
        if pairs.len() > rows {
            bail!("batch of {} exceeds {} rows", pairs.len(), rows);
        }
        let before = self.crossbar.metrics;
        for (r, &(a, b)) in pairs.iter().enumerate() {
            self.compiled.load_pair(&mut self.crossbar.state, r, a, b)?;
        }
        let delta = self.run_prepared_batch(before)?;
        let mut out = Vec::with_capacity(pairs.len());
        for r in 0..pairs.len() {
            out.push(self.compiled.read_result(&self.crossbar.state, r)?);
        }
        Ok((out, delta))
    }

    /// Execute one chunk payload end-to-end: the single entry point the
    /// scheduler's worker threads use. Loader or readback errors come back
    /// as `Err` (they fail the chunk's job, not the worker); only a genuine
    /// panic — a simulated hardware fault — takes the worker down.
    pub fn run_payload(&mut self, payload: &Payload) -> Result<(ChunkValues, Metrics)> {
        match payload {
            Payload::Pairs(pairs) => {
                let (v, m) = self.run_batch(pairs)?;
                Ok((ChunkValues::Scalars(v), m))
            }
            Payload::Rows(rows_data) => {
                let (v, m) = self.run_sort_batch(rows_data)?;
                Ok((ChunkValues::Rows(v), m))
            }
            Payload::Poison => panic!("injected crossbar fault"),
        }
    }

    /// Execute one row-batch of sort jobs (one 16-element vector per row).
    pub fn run_sort_batch(&mut self, rows_data: &[Vec<u64>]) -> Result<(Vec<Vec<u64>>, Metrics)> {
        let Compiled::Sorter(sorter) = &self.compiled else {
            bail!("run_sort_batch on a non-sort workload");
        };
        if rows_data.len() > self.crossbar.geom.rows {
            bail!("batch of {} exceeds {} rows", rows_data.len(), self.crossbar.geom.rows);
        }
        let before = self.crossbar.metrics;
        for (r, vals) in rows_data.iter().enumerate() {
            sorter.load(&mut self.crossbar.state, r, vals)?;
        }
        let delta = self.run_prepared_batch(before)?;
        let Compiled::Sorter(sorter) = &self.compiled else { unreachable!() };
        let mut out = Vec::with_capacity(rows_data.len());
        for r in 0..rows_data.len() {
            out.push(sorter.read(&self.crossbar.state, r)?);
        }
        Ok((out, delta))
    }
}

/// Choose the geometry a workload/model combination needs.
pub fn workload_geometry(kind: WorkloadKind, model: ModelKind, rows: usize) -> Geometry {
    match (kind, model) {
        // Serial baselines run on a partition-free crossbar.
        (_, ModelKind::Baseline) => Geometry::new(1024, 1, rows).expect("static geometry"),
        // MultPIM at paper scale: n=1024, k=32 (one partition per bit).
        (WorkloadKind::Mul32, _) => Geometry::paper(rows),
        (WorkloadKind::Add32, _) => Geometry::new(1024, 32, rows).expect("static geometry"),
        // One element per partition: 16 partitions.
        (WorkloadKind::Sort16, _) => Geometry::new(512, SORT_ELEMS, rows).expect("static geometry"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_multiplies_batches() {
        for model in [ModelKind::Baseline, ModelKind::Minimal, ModelKind::Standard, ModelKind::Unlimited] {
            let geom = workload_geometry(WorkloadKind::Mul32, model, 16);
            let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
            let pairs: Vec<(u64, u64)> = (0..16).map(|i| (0xabcd1234 ^ (i * 77), 0x1357 + i * 991)).collect();
            let (out, metrics) = w.run_batch(&pairs).unwrap();
            for (i, &(a, b)) in pairs.iter().enumerate() {
                assert_eq!(out[i], a * b, "{}*{} under {}", a, b, model.name());
            }
            assert!(metrics.cycles > 0 && metrics.control_bits > 0);
        }
    }

    #[test]
    fn worker_adds_batches() {
        let geom = workload_geometry(WorkloadKind::Add32, ModelKind::Minimal, 8);
        let mut w = Worker::new(WorkloadKind::Add32, ModelKind::Minimal, geom).unwrap();
        let pairs: Vec<(u64, u64)> = (0..8).map(|i| (0xffff_ffff - i, i * 3)).collect();
        let (out, _) = w.run_batch(&pairs).unwrap();
        for (i, &(a, b)) in pairs.iter().enumerate() {
            assert_eq!(out[i], a + b);
        }
    }

    /// The model hierarchy must order latency: unlimited <= standard <= minimal,
    /// all far below the serial baseline (Figure 6(a) shape).
    #[test]
    fn model_latency_ordering() {
        let cycles = |model: ModelKind| {
            let geom = workload_geometry(WorkloadKind::Mul32, model, 1);
            Worker::new(WorkloadKind::Mul32, model, geom).unwrap().batch_cycles()
        };
        let (base, unl, std_, min) = (
            cycles(ModelKind::Baseline),
            cycles(ModelKind::Unlimited),
            cycles(ModelKind::Standard),
            cycles(ModelKind::Minimal),
        );
        assert!(unl <= std_ && std_ <= min, "unl={unl} std={std_} min={min}");
        assert!(base > 5 * min, "serial baseline {base} must dwarf partitioned {min}");
    }

    /// The per-batch metrics delta must charge exactly the wire format's
    /// control bits per gate cycle plus one write command per init cycle.
    #[test]
    fn batch_delta_meters_control_exactly() {
        let model = ModelKind::Minimal;
        let geom = workload_geometry(WorkloadKind::Mul32, model, 4);
        let mut w = Worker::new(WorkloadKind::Mul32, model, geom).unwrap();
        let pairs: Vec<(u64, u64)> = (0..4).map(|i| (i + 1, 3 * i + 2)).collect();
        let (_, m) = w.run_batch(&pairs).unwrap();
        let gate_msg = crate::isa::encode::message_bits(model, &geom) as u64;
        let init_msg = crate::crossbar::crossbar::init_message_bits(&geom) as u64;
        assert_eq!(m.control_bits, m.gate_cycles * gate_msg + m.init_cycles * init_msg);
        assert_eq!(m.messages, m.cycles);
    }
}
