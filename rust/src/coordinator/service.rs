//! The controller service: a concurrent, fault-isolated job scheduler over
//! a bank of crossbar workers, with cross-job chunk coalescing.
//!
//! Jobs are split into row-segments that flow through a central dispatcher;
//! a [`crate::coordinator::coalesce::Coalescer`] packs partial segments
//! from different jobs into shared full-occupancy row-batches before they
//! reach a worker:
//!
//! ```text
//!   clients ──Register/Enqueue──▶ Dispatcher ──batches──▶ Worker threads
//!      ▲                           │ job table │ coalescer   │
//!      └───── JobHandle::wait ◀────┴──────── Done/Exit ◀─────┘
//! ```
//!
//! * [`PimService::submit`] / [`PimService::submit_sort`] are non-blocking:
//!   they hand the job to the dispatcher and return a [`JobHandle`]. Any
//!   number of jobs can be in flight; completions are routed by job id, so
//!   segments of different jobs interleave freely across the bank — and,
//!   after coalescing, even within one batch.
//! * The crossbar is row-parallel, so a batch costs the same whether 1 or
//!   all rows hold operands. The coalescer therefore packs small jobs
//!   together (greedy first-fit up to full occupancy, with a short linger
//!   window for underfull batches — see `coalesce.rs`), and per-job metrics
//!   become attribution over the shared batch: occupancy-proportional
//!   `sim_cycles`/`control_bits`, exact row-range `switch_events`.
//! * Workers *pull* batches (the dispatcher assigns work only to idle, live
//!   workers), so a dead worker never strands queued work.
//! * A segment failure (malformed operand, readback error) fails only its
//!   own job: co-batched segments still complete, the worker keeps serving,
//!   the failed job's handle resolves to `Err` immediately, and its
//!   remaining segments are drained without poisoning any other job.
//! * A crashed worker (panic mid-batch, or [`PimService::kill_worker`])
//!   retires from the bank; a batch it had accepted but not executed is
//!   requeued to the surviving workers. A batch that was *executing* when
//!   the crossbar died fails every job aboard (they shared the hardware).
//!   Only when *every* worker is gone do pending jobs fail.
//! * Serving is wear- and reliability-aware (DESIGN.md §Wear): the bank
//!   keeps a persistent per-row [`crate::crossbar::WearMap`] fed by the
//!   exact switch attribution of every batch, places batches on the
//!   coldest healthy rows when `wear_leveling` is on, quarantines rows
//!   found stuck-at ([`PimService::inject_stuck`]) and transparently
//!   remaps their segments onto healthy rows within a bounded budget —
//!   failing typed ([`RowQuarantined`]) only when capacity runs out — and
//!   reports the endurance horizon in [`ServiceStats::wear`].

use crate::backend::ReplayMode;
use crate::coordinator::coalesce::Coalescer;
use crate::coordinator::worker::{workload_geometry, ChunkValues, JobShape, Payload, Segment, SegmentReport, Worker, WorkloadKind};
use crate::crossbar::crossbar::Metrics;
use crate::crossbar::faults::{FaultMap, StuckAt};
use crate::crossbar::geometry::Geometry;
use crate::crossbar::wear::{WearMap, WearSummary};
use crate::isa::models::ModelKind;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Typed error: a submission whose operand shape this bank's workload
/// cannot execute — an element-wise job on a sort bank, or a per-row sort
/// job on an arithmetic bank. Both mismatch directions resolve to this one
/// type; the fleet router matches on it (`downcast_ref::<WorkloadMismatch>`)
/// to tell a routing bug apart from a genuine job failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkloadMismatch {
    /// The workload the service was started with.
    pub service: WorkloadKind,
    /// The shape the submission required.
    pub submitted: JobShape,
}

impl std::fmt::Display for WorkloadMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "workload mismatch: service runs {} ({}), but the job carries {}",
            self.service.name(),
            self.service.shape(),
            self.submitted
        )
    }
}

impl std::error::Error for WorkloadMismatch {}

/// Typed error: the job was lost to its bank dying — every crossbar worker
/// is gone. The fleet layer matches on this (`downcast_ref::<BankDead>`) to
/// requeue the job onto a compatible bank or a promoted hot spare instead
/// of surfacing the failure; a standalone service surfaces it directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BankDead {
    /// True when the job had been accepted before the bank died (its
    /// segments were pending); false when the registration itself was
    /// rejected because no live worker was left.
    pub accepted: bool,
}

impl std::fmt::Display for BankDead {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.accepted {
            f.write_str("every crossbar worker in the bank has failed")
        } else {
            f.write_str("no live crossbar workers left in the bank")
        }
    }
}

impl std::error::Error for BankDead {}

/// Typed error: a job segment could not be (re)placed on healthy rows —
/// stuck-at quarantine shrank the bank below the segment's span, or the
/// segment exhausted its bounded remap budget. Carried as the failure
/// detail of the affected job (`downcast_ref::<RowQuarantined>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowQuarantined {
    /// Rows the segment needs.
    pub rows_needed: usize,
    /// Healthy rows the bank has left.
    pub healthy_rows: usize,
    /// Remap attempts the segment had already used.
    pub remaps: u32,
}

impl std::fmt::Display for RowQuarantined {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "segment of {} row(s) cannot be placed: {} healthy row(s) left after stuck-at quarantine ({} remap(s) attempted)",
            self.rows_needed, self.healthy_rows, self.remaps
        )
    }
}

impl std::error::Error for RowQuarantined {}

/// Typed error: a result accessor asked for the wrong value shape —
/// [`JobValues::try_scalars`] on a sort job, or [`JobValues::try_rows`] on
/// an element-wise one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ValueShapeMismatch {
    /// The shape the accessor requested.
    pub requested: JobShape,
    /// The shape the job actually produced.
    pub actual: JobShape,
}

impl std::fmt::Display for ValueShapeMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "value shape mismatch: accessor requested {}, but the job produced {}", self.requested, self.actual)
    }
}

impl std::error::Error for ValueShapeMismatch {}

/// Service configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    pub kind: WorkloadKind,
    pub model: ModelKind,
    /// Crossbars (= worker threads) in the bank.
    pub n_crossbars: usize,
    /// Rows per crossbar (elements per batch chunk).
    pub rows: usize,
    /// Cross-job chunk coalescing: pack partial chunks from different jobs
    /// into one shared row-batch up to full occupancy. Disable only for the
    /// serialized ablation (`benches/coalescing_bench.rs`).
    pub coalescing: bool,
    /// How long an underfull batch may wait for co-tenants before it is
    /// dispatched anyway (bounds the latency a lone small job can pay).
    pub linger: Duration,
    /// How workers replay the prepared workload program per batch: the
    /// decode-once trusted op cache (default) or the full wire re-decode
    /// (the differential-testing escape hatch — see DESIGN.md §Replay fast
    /// path).
    pub replay_mode: ReplayMode,
    /// Word-range executor threads each worker may use per decoded replay
    /// (1 = serial; capped by the crossbar's `rows/64` word count).
    pub replay_threads: usize,
    /// Wear-leveling placement: pack each batch onto the coldest healthy
    /// rows instead of front-packing, spreading switch events across the
    /// array. Disable only for the wear ablation (`benches/wear_bench.rs`),
    /// mirroring the `coalescing` flag.
    pub wear_leveling: bool,
    /// How many times one segment may be remapped off freshly quarantined
    /// stuck-at rows before its job fails typed ([`RowQuarantined`]).
    pub max_remaps: u32,
    /// Per-row endurance budget in switch events, used to project the
    /// time-to-first-failure horizon in [`ServiceStats::wear`]. `None`
    /// leaves the horizon unreported.
    pub endurance_budget: Option<u64>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 4,
            rows: 64,
            coalescing: true,
            linger: Duration::from_micros(200),
            replay_mode: ReplayMode::Decoded,
            replay_threads: 1,
            wear_leveling: true,
            max_remaps: 3,
            endurance_budget: None,
        }
    }
}

/// Values a completed job produced: scalars for element-wise arithmetic,
/// one vector per row for sort jobs, one permuted Keccak state per row for
/// sha3 jobs.
#[derive(Debug, Clone)]
pub enum JobValues {
    Scalars(Vec<u64>),
    Rows(Vec<Vec<u64>>),
    States(Vec<[u64; 25]>),
}

impl JobValues {
    /// The shape these values carry, mirroring [`JobShape`].
    pub fn shape(&self) -> JobShape {
        match self {
            JobValues::Scalars(_) => JobShape::ElementWise,
            JobValues::Rows(_) => JobShape::RowVectors,
            JobValues::States(_) => JobShape::KeccakState,
        }
    }

    /// Element-wise results, or a typed [`ValueShapeMismatch`] if the job
    /// produced a different shape.
    pub fn try_scalars(&self) -> std::result::Result<&[u64], ValueShapeMismatch> {
        match self {
            JobValues::Scalars(v) => Ok(v),
            other => Err(ValueShapeMismatch { requested: JobShape::ElementWise, actual: other.shape() }),
        }
    }

    /// Per-row sorted vectors, or a typed [`ValueShapeMismatch`] if the job
    /// produced a different shape.
    pub fn try_rows(&self) -> std::result::Result<&[Vec<u64>], ValueShapeMismatch> {
        match self {
            JobValues::Rows(r) => Ok(r),
            other => Err(ValueShapeMismatch { requested: JobShape::RowVectors, actual: other.shape() }),
        }
    }

    /// Per-row permuted Keccak states, or a typed [`ValueShapeMismatch`] if
    /// the job produced a different shape.
    pub fn try_states(&self) -> std::result::Result<&[[u64; 25]], ValueShapeMismatch> {
        match self {
            JobValues::States(s) => Ok(s),
            other => Err(ValueShapeMismatch { requested: JobShape::KeccakState, actual: other.shape() }),
        }
    }

    /// Element-wise results.
    ///
    /// # Panics
    ///
    /// Panics if the job was a sort job. Meant for benches and examples
    /// where the workload is fixed by construction; fallible callers use
    /// [`JobValues::try_scalars`].
    pub fn scalars(&self) -> &[u64] {
        match self {
            JobValues::Scalars(v) => v,
            _ => panic!("job produced per-row results, not scalars"),
        }
    }

    /// Per-row sorted vectors.
    ///
    /// # Panics
    ///
    /// Panics if the job was element-wise. Meant for benches and examples
    /// where the workload is fixed by construction; fallible callers use
    /// [`JobValues::try_rows`].
    pub fn rows(&self) -> &[Vec<u64>] {
        match self {
            JobValues::Rows(r) => r,
            _ => panic!("job produced scalar results, not rows"),
        }
    }

    /// Per-row permuted Keccak states.
    ///
    /// # Panics
    ///
    /// Panics if the job was not a sha3 job. Meant for benches and examples
    /// where the workload is fixed by construction; fallible callers use
    /// [`JobValues::try_states`].
    pub fn states(&self) -> &[[u64; 25]] {
        match self {
            JobValues::States(s) => s,
            _ => panic!("job produced {} results, not keccak states", self.shape()),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            JobValues::Scalars(v) => v.len(),
            JobValues::Rows(r) => r.len(),
            JobValues::States(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Completed-job report (shared by element-wise and sort jobs).
///
/// When the job's segments rode coalesced batches, `sim_cycles` and
/// `control_bits` are its occupancy-proportional share of each shared
/// batch, while `switch_events` counts exactly the memristor flips inside
/// the job's own row ranges (see `coordinator::worker::SegmentReport`).
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub values: JobValues,
    /// Simulated crossbar cycles attributed to this job (summed).
    pub sim_cycles: u64,
    /// Control traffic attributed to this job, in bits.
    pub control_bits: u64,
    /// Memristor switching events inside this job's row ranges (exact —
    /// the per-job energy signal the ghost-row bug used to pollute).
    pub switch_events: u64,
    /// Wall-clock service latency, submit to completion.
    pub wall: std::time::Duration,
}

impl JobResult {
    /// Element-wise results, or a typed [`ValueShapeMismatch`] on a sort job.
    pub fn try_scalars(&self) -> std::result::Result<&[u64], ValueShapeMismatch> {
        self.values.try_scalars()
    }

    /// Per-row sorted vectors, or a typed [`ValueShapeMismatch`] on an
    /// element-wise job.
    pub fn try_rows(&self) -> std::result::Result<&[Vec<u64>], ValueShapeMismatch> {
        self.values.try_rows()
    }

    /// Element-wise results.
    ///
    /// # Panics
    ///
    /// Panics on a sort job (see [`JobValues::scalars`]; bench-only use).
    pub fn scalars(&self) -> &[u64] {
        self.values.scalars()
    }

    /// Per-row sorted vectors.
    ///
    /// # Panics
    ///
    /// Panics on an element-wise job (see [`JobValues::rows`]; bench-only use).
    pub fn rows(&self) -> &[Vec<u64>] {
        self.values.rows()
    }
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServiceStats {
    /// Jobs completed successfully.
    pub jobs: u64,
    /// Jobs that failed (bad operands, crashed worker, dead bank).
    pub failed_jobs: u64,
    /// Elements processed by successfully executed segments.
    pub elements: u64,
    /// Segments (per-job chunks) executed successfully.
    pub chunks: u64,
    /// Shared row-batches executed (a batch carries >= 1 segment).
    pub batches: u64,
    /// Rows carrying operands across executed batches.
    pub occupied_rows: u64,
    /// Row capacity across executed batches (`batches * rows`).
    pub capacity_rows: u64,
    /// Segments transparently remapped off freshly quarantined stuck-at
    /// rows (each also re-counts into `occupied_rows` when its retry
    /// executes).
    pub remapped_segments: u64,
    pub metrics: Metrics,
    /// Endurance-horizon report: per-row wear spread and the projected
    /// time-to-first-failure under `ServiceConfig::endurance_budget`.
    pub wear: WearSummary,
}

impl ServiceStats {
    /// Mean batch occupancy in [0, 1]: the fraction of the bank's row
    /// parallelism that carried operands — the utilization the coalescer
    /// exists to maximize.
    pub fn mean_occupancy(&self) -> f64 {
        if self.capacity_rows == 0 {
            0.0
        } else {
            self.occupied_rows as f64 / self.capacity_rows as f64
        }
    }

    /// Fold another bank's statistics into this one (fleet aggregation:
    /// `FleetStats` merges the per-bank `ServiceStats` of every live,
    /// dead and retired bank).
    pub fn merge(&mut self, other: &ServiceStats) {
        self.jobs += other.jobs;
        self.failed_jobs += other.failed_jobs;
        self.elements += other.elements;
        self.chunks += other.chunks;
        self.batches += other.batches;
        self.occupied_rows += other.occupied_rows;
        self.capacity_rows += other.capacity_rows;
        self.remapped_segments += other.remapped_segments;
        self.metrics.add(&other.metrics);
        self.wear.merge(&other.wear);
    }
}

/// Job id reserved for fault-injection poison segments (never a real job).
const POISON_JOB: u64 = u64::MAX;

/// One coalesced unit of work: segments from any number of jobs, placed
/// into a single shared row-batch by the dispatcher's wear-aware planner.
struct Batch {
    segments: Vec<Segment>,
    /// Row placement: `plan[i]` is the ascending row list segment `i`
    /// occupies (`WearMap::assign_rows` — coldest healthy rows under
    /// leveling, front-packed otherwise).
    plan: Vec<Vec<usize>>,
}

/// Everything the dispatcher hears: job registration and segment supply
/// from clients, pull requests and completions from workers, fault
/// injection and shutdown from the service front-end.
enum Event {
    Register { id: u64, accum: JobValues, n_chunks: usize, start: Instant, result_tx: Sender<Result<JobResult>> },
    Enqueue(Segment),
    Ready(usize),
    /// Per-segment outcomes of one batch. `segments` travel back with the
    /// reports so stuck-row segments can be requeued for remap without the
    /// client resubmitting; `row_wear` is the batch's per-row switch
    /// snapshot for the bank wear map. `executed` is false when the batch
    /// failed wholesale before the shared program ran (its reports then
    /// carry the batch error and zero metrics).
    Done { segments: Vec<Segment>, reports: Vec<SegmentReport>, row_wear: Vec<u64>, metrics: Metrics, executed: bool },
    WorkerExit { worker: usize, unfinished: Option<Batch>, crashed: bool },
    KillWorker(usize),
    Shutdown,
}

struct JobState {
    /// Result accumulator, filled in by offset as completions arrive.
    accum: JobValues,
    /// Segments not yet resolved (completed, failed, or drained).
    outstanding: usize,
    sim_cycles: u64,
    control_bits: u64,
    switch_events: u64,
    start: Instant,
    /// Taken when the final result (or the first error) is delivered.
    result_tx: Option<Sender<Result<JobResult>>>,
    failed: bool,
}

struct WorkerPort {
    /// Dropped to wake and retire the worker.
    tx: Option<Sender<Batch>>,
    /// Abrupt-kill flag: the worker checks it before executing a batch and
    /// hands the batch back unexecuted if set.
    kill: Arc<AtomicBool>,
    alive: bool,
    idle: bool,
}

/// What happened to one segment of a job.
enum ChunkOutcome {
    Success { offset: usize, values: ChunkValues, sim_cycles: u64, control_bits: u64, switch_events: u64 },
    /// The segment failed; typed errors ([`RowQuarantined`], batch faults)
    /// flow through to the job handle for `downcast_ref` matching.
    Failure(anyhow::Error),
    /// Queued segment of an already-failed job, drained without executing.
    Drained,
}

struct Dispatcher {
    rx: Receiver<Event>,
    ports: Vec<WorkerPort>,
    coalescer: Coalescer,
    /// Row capacity of one batch (occupancy accounting).
    rows: usize,
    jobs: HashMap<u64, JobState>,
    stats: Arc<Mutex<ServiceStats>>,
    /// The bank's persistent wear + quarantine ledger (shared with
    /// `PimService::wear` snapshots). Drives batch placement.
    wear: Arc<Mutex<WearMap>>,
    /// Place batches on the coldest healthy rows (`ServiceConfig::wear_leveling`).
    wear_leveling: bool,
    /// Bounded per-segment remap budget (`ServiceConfig::max_remaps`).
    max_remaps: u32,
    /// Endurance budget for the horizon projection in `ServiceStats::wear`.
    endurance_budget: Option<u64>,
    /// Service start (the observation window of the horizon projection).
    started: Instant,
    /// Jobs submitted but not yet resolved (shared with the clients, which
    /// increment it at submit) — the queue-depth signal the fleet router
    /// and admission control read. Decremented exactly when a job's result
    /// (or first error) is delivered, or its registration is rejected.
    pending: Arc<AtomicU64>,
    /// Live workers in the bank (the fleet's liveness signal). Decremented
    /// once per worker, on whichever event retires it first.
    live: Arc<AtomicUsize>,
    shutting_down: bool,
}

impl Dispatcher {
    fn run(mut self) {
        loop {
            // While an underfull batch lingers for co-tenants *and* a worker
            // is idle to take it, sleep only until its window expires;
            // otherwise block until the next event.
            let ev = if self.awaiting_linger() {
                let deadline = self.coalescer.deadline().expect("lingering implies a pending segment");
                match self.rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                    Ok(ev) => Some(ev),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            } else {
                match self.rx.recv() {
                    Ok(ev) => Some(ev),
                    Err(_) => break,
                }
            };
            if let Some(ev) = ev {
                self.handle(ev);
            }
            self.assign();
            if self.shutting_down && self.jobs.is_empty() && self.coalescer.is_empty() {
                break;
            }
        }
        // Whatever is still pending when the dispatcher exits resolves to an
        // error rather than a hang.
        for (_, job) in self.jobs.drain() {
            if let Some(tx) = job.result_tx {
                self.pending.fetch_sub(1, Ordering::SeqCst);
                let _ = tx.send(Err(anyhow!("service shut down before the job completed")));
            }
        }
    }

    /// True while the only obstacle to dispatching is an open linger window:
    /// segments are pending, a live worker is idle, and the coalescer has a
    /// deadline to wake up for.
    fn awaiting_linger(&self) -> bool {
        self.coalescer.deadline().is_some() && self.ports.iter().any(|p| p.alive && p.idle)
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Register { id, accum, n_chunks, start, result_tx } => {
                if self.shutting_down {
                    self.stats.lock().unwrap().failed_jobs += 1;
                    self.pending.fetch_sub(1, Ordering::SeqCst);
                    let _ = result_tx.send(Err(anyhow!("service is shutting down")));
                } else if !self.ports.iter().any(|p| p.alive) {
                    self.stats.lock().unwrap().failed_jobs += 1;
                    self.pending.fetch_sub(1, Ordering::SeqCst);
                    let _ = result_tx.send(Err(anyhow::Error::new(BankDead { accepted: false })));
                } else {
                    self.jobs.insert(
                        id,
                        JobState {
                            accum,
                            outstanding: n_chunks,
                            sim_cycles: 0,
                            control_bits: 0,
                            switch_events: 0,
                            start,
                            result_tx: Some(result_tx),
                            failed: false,
                        },
                    );
                }
            }
            Event::Enqueue(seg) => {
                // Segments of a rejected registration are dropped here, as
                // are poison segments aimed at an already-dead bank (they
                // could never drain and would wedge shutdown).
                let accept = if seg.job == POISON_JOB {
                    self.ports.iter().any(|p| p.alive)
                } else {
                    self.jobs.contains_key(&seg.job)
                };
                if accept {
                    self.coalescer.push_back(seg, Instant::now());
                }
            }
            Event::Ready(w) => self.ports[w].idle = true,
            Event::Done { segments, reports, row_wear, metrics, executed } => {
                if executed {
                    // Wear is physical: it accumulates whether or not any
                    // job aboard succeeded.
                    self.wear.lock().unwrap_or_else(|e| e.into_inner()).absorb(&row_wear);
                }
                {
                    let mut s = self.stats.lock().unwrap();
                    if executed {
                        s.batches += 1;
                        s.capacity_rows += self.rows as u64;
                        s.occupied_rows += reports.iter().map(|r| r.span as u64).sum::<u64>();
                        s.metrics.add(&metrics);
                    }
                    for r in &reports {
                        if r.values.is_ok() {
                            s.chunks += 1;
                            s.elements += r.span as u64;
                        }
                    }
                }
                for (seg, r) in segments.into_iter().zip(reports) {
                    let SegmentReport { job, offset, span: _, values, sim_cycles, control_bits, switch_events, stuck_rows } = r;
                    if !stuck_rows.is_empty() {
                        // Stuck-at detection: the segment's values are
                        // invalid, but the rows — not the job — are at
                        // fault. Quarantine and retry instead of failing.
                        self.handle_stuck(seg, &stuck_rows);
                        continue;
                    }
                    let outcome = match values {
                        Ok(values) => ChunkOutcome::Success { offset, values, sim_cycles, control_bits, switch_events },
                        Err(msg) => ChunkOutcome::Failure(anyhow!(msg).context(format!("chunk at offset {offset}"))),
                    };
                    self.resolve_chunk(job, outcome);
                }
                self.refresh_wear_summary();
            }
            Event::WorkerExit { worker, unfinished, crashed } => {
                let port = &mut self.ports[worker];
                if port.alive {
                    self.live.fetch_sub(1, Ordering::SeqCst);
                }
                port.alive = false;
                port.idle = false;
                port.tx = None;
                match unfinished {
                    // A panic mid-batch takes down every job aboard: the
                    // co-batched segments physically shared the dying
                    // crossbar, and the batch is the prime suspect, so it
                    // is not retried against another worker.
                    Some(batch) if crashed => {
                        for seg in batch.segments {
                            self.resolve_chunk(
                                seg.job,
                                ChunkOutcome::Failure(anyhow!(
                                    "worker {worker} crashed executing the shared batch (chunk at offset {})",
                                    seg.offset
                                )),
                            );
                        }
                    }
                    // Killed before executing: the batch is innocent,
                    // requeue its segments to the surviving workers.
                    Some(batch) => self.coalescer.push_front(batch.segments, Instant::now()),
                    None => {}
                }
                self.fail_all_if_bank_dead();
            }
            Event::KillWorker(w) => {
                let port = &mut self.ports[w];
                if port.alive {
                    self.live.fetch_sub(1, Ordering::SeqCst);
                    port.kill.store(true, Ordering::SeqCst);
                    port.alive = false;
                    // Dropping the channel wakes an idle worker so it can
                    // observe the kill flag and retire.
                    port.tx = None;
                }
                self.fail_all_if_bank_dead();
            }
            Event::Shutdown => self.shutting_down = true,
        }
    }

    /// A segment came back with stuck-at rows in its placement: quarantine
    /// the rows (they never serve again — stuck devices do not heal), shrink
    /// the coalescer to the healthy capacity, and requeue the segment for a
    /// remap onto healthy rows within its bounded budget. The segment stays
    /// outstanding and nothing was charged to its job, so the eventual
    /// completion is value- and metric-identical to a fault-free run. Only
    /// when the budget or the healthy capacity runs out does the job fail,
    /// typed ([`RowQuarantined`]).
    fn handle_stuck(&mut self, mut seg: Segment, stuck: &[usize]) {
        let healthy = {
            let mut wear = self.wear.lock().unwrap_or_else(|e| e.into_inner());
            for &row in stuck {
                wear.quarantine(row);
            }
            wear.healthy_rows()
        };
        self.coalescer.set_capacity(healthy);
        let span = seg.payload.len();
        if seg.remaps < self.max_remaps && span <= healthy {
            seg.remaps += 1;
            self.stats.lock().unwrap().remapped_segments += 1;
            // Ahead of the line: the job already waited one batch, and a
            // requeued segment never re-lingers.
            self.coalescer.push_front(vec![seg], Instant::now());
        } else {
            let job = seg.job;
            let detail = RowQuarantined { rows_needed: span, healthy_rows: healthy, remaps: seg.remaps };
            self.resolve_chunk(job, ChunkOutcome::Failure(anyhow::Error::new(detail)));
        }
    }

    /// Recompute the endurance-horizon report after wear moved (batch
    /// completion) or rows left service (quarantine).
    fn refresh_wear_summary(&self) {
        let summary = self
            .wear
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .summarize(self.started.elapsed().as_secs_f64(), self.endurance_budget);
        self.stats.lock().unwrap().wear = summary;
    }

    /// Fold one segment resolution into its job; deliver the final result
    /// (or the first error) and retire the job once every segment is
    /// accounted for.
    fn resolve_chunk(&mut self, job_id: u64, outcome: ChunkOutcome) {
        let Some(job) = self.jobs.get_mut(&job_id) else {
            return; // poison segment, or a job already finalized
        };
        match outcome {
            ChunkOutcome::Success { offset, values, sim_cycles, control_bits, switch_events } => {
                if !job.failed {
                    match (&mut job.accum, values) {
                        (JobValues::Scalars(acc), ChunkValues::Scalars(vs)) => {
                            for (i, v) in vs.into_iter().enumerate() {
                                acc[offset + i] = v;
                            }
                        }
                        (JobValues::Rows(acc), ChunkValues::Rows(rs)) => {
                            for (i, r) in rs.into_iter().enumerate() {
                                acc[offset + i] = r;
                            }
                        }
                        (JobValues::States(acc), ChunkValues::States(sts)) => {
                            for (i, st) in sts.into_iter().enumerate() {
                                acc[offset + i] = st;
                            }
                        }
                        // Unreachable: a job's payload kind is fixed at submit.
                        _ => {}
                    }
                    job.sim_cycles += sim_cycles;
                    job.control_bits += control_bits;
                    job.switch_events += switch_events;
                }
            }
            ChunkOutcome::Failure(err) => {
                if !job.failed {
                    job.failed = true;
                    if let Some(tx) = job.result_tx.take() {
                        self.pending.fetch_sub(1, Ordering::SeqCst);
                        let _ = tx.send(Err(err));
                    }
                    self.stats.lock().unwrap().failed_jobs += 1;
                }
            }
            ChunkOutcome::Drained => {}
        }
        let Some(job) = self.jobs.get_mut(&job_id) else { return };
        job.outstanding -= 1;
        if job.outstanding == 0 {
            let job = self.jobs.remove(&job_id).expect("job present");
            if !job.failed {
                self.stats.lock().unwrap().jobs += 1;
                if let Some(tx) = job.result_tx {
                    self.pending.fetch_sub(1, Ordering::SeqCst);
                    let _ = tx.send(Ok(JobResult {
                        id: job_id,
                        values: job.accum,
                        sim_cycles: job.sim_cycles,
                        control_bits: job.control_bits,
                        switch_events: job.switch_events,
                        wall: job.start.elapsed(),
                    }));
                }
            }
        }
    }

    /// Hand dispatchable batches to idle, live workers until one of the two
    /// runs out. Dead jobs' queued segments are drained first so they never
    /// occupy batch rows.
    fn assign(&mut self) {
        loop {
            if !self.ports.iter().any(|p| p.alive && p.idle) {
                return;
            }
            let jobs = &self.jobs;
            let dead = self
                .coalescer
                .drain_dead(|seg| seg.job != POISON_JOB && !matches!(jobs.get(&seg.job).map(|j| j.failed), Some(false)));
            for seg in dead {
                self.resolve_chunk(seg.job, ChunkOutcome::Drained);
            }
            let Some(segments) = self.coalescer.pop_batch(Instant::now(), self.shutting_down) else {
                return;
            };
            // Wear-aware placement: coldest healthy rows under leveling,
            // the historical front-packed layout otherwise. `None` means
            // stuck-at quarantine shrank the bank below this batch — its
            // segments fail typed, they can never be placed again.
            let spans: Vec<usize> = segments.iter().map(|s| s.payload.len()).collect();
            let (plan, healthy) = {
                let wear = self.wear.lock().unwrap_or_else(|e| e.into_inner());
                (wear.assign_rows(&spans, self.wear_leveling), wear.healthy_rows())
            };
            let Some(plan) = plan else {
                for seg in segments {
                    let detail = RowQuarantined { rows_needed: seg.payload.len(), healthy_rows: healthy, remaps: seg.remaps };
                    self.resolve_chunk(seg.job, ChunkOutcome::Failure(anyhow::Error::new(detail)));
                }
                continue;
            };
            let mut batch = Batch { segments, plan };
            loop {
                let Some(w) = self.ports.iter().position(|p| p.alive && p.idle) else {
                    self.coalescer.push_front(batch.segments, Instant::now());
                    return;
                };
                let Some(tx) = self.ports[w].tx.clone() else {
                    self.live.fetch_sub(1, Ordering::SeqCst);
                    self.ports[w].alive = false;
                    continue;
                };
                match tx.send(batch) {
                    Ok(()) => {
                        self.ports[w].idle = false;
                        break;
                    }
                    Err(std::sync::mpsc::SendError(b)) => {
                        // The worker died without telling us yet; its exit
                        // event will follow. Try the next live worker.
                        self.live.fetch_sub(1, Ordering::SeqCst);
                        self.ports[w].alive = false;
                        self.ports[w].tx = None;
                        batch = b;
                    }
                }
            }
        }
    }

    /// When the last worker retires, every pending job fails cleanly instead
    /// of hanging its handle.
    fn fail_all_if_bank_dead(&mut self) {
        if self.ports.iter().any(|p| p.alive) {
            return;
        }
        self.coalescer.clear();
        let mut newly_failed = 0u64;
        for (_, mut job) in self.jobs.drain() {
            if !job.failed {
                newly_failed += 1;
                if let Some(tx) = job.result_tx.take() {
                    self.pending.fetch_sub(1, Ordering::SeqCst);
                    let _ = tx.send(Err(anyhow::Error::new(BankDead { accepted: true })));
                }
            }
        }
        if newly_failed > 0 {
            self.stats.lock().unwrap().failed_jobs += newly_failed;
        }
    }
}

/// Worker thread body: pull a coalesced batch, execute it once, report the
/// per-segment outcomes. Segment errors ride inside the reports and the
/// loop continues; a whole-batch error fails every segment aboard (the
/// worker still keeps serving); only a panic (simulated hardware fault)
/// retires the worker after notifying the dispatcher.
fn worker_loop(i: usize, mut worker: Worker, rx: Receiver<Batch>, event_tx: Sender<Event>, kill: Arc<AtomicBool>) {
    loop {
        if event_tx.send(Event::Ready(i)).is_err() {
            return;
        }
        let batch = match rx.recv() {
            Ok(b) => b,
            Err(_) => {
                let _ = event_tx.send(Event::WorkerExit { worker: i, unfinished: None, crashed: false });
                return;
            }
        };
        if kill.load(Ordering::SeqCst) {
            // Abrupt retirement: hand the accepted-but-unexecuted batch back.
            let _ = event_tx.send(Event::WorkerExit { worker: i, unfinished: Some(batch), crashed: false });
            return;
        }
        match catch_unwind(AssertUnwindSafe(|| worker.run_segments_placed(&batch.segments, &batch.plan))) {
            Ok(Ok((reports, row_wear, metrics))) => {
                // A batch whose every segment failed to load skips the
                // shared replay entirely (zero cycles): it occupied no bank
                // time, so it does not count into occupancy statistics.
                let executed = metrics.cycles > 0;
                if event_tx.send(Event::Done { segments: batch.segments, reports, row_wear, metrics, executed }).is_err() {
                    return;
                }
            }
            Ok(Err(e)) => {
                // Whole-batch failure (occupancy overflow, pipeline fault):
                // the shared program never completed, so every segment
                // aboard fails with the batch error.
                let msg = format!("{e:#}");
                let reports = batch
                    .segments
                    .iter()
                    .map(|s| SegmentReport {
                        job: s.job,
                        offset: s.offset,
                        span: s.payload.len(),
                        values: Err(msg.clone()),
                        sim_cycles: 0,
                        control_bits: 0,
                        switch_events: 0,
                        stuck_rows: Vec::new(),
                    })
                    .collect();
                let done =
                    Event::Done { segments: batch.segments, reports, row_wear: Vec::new(), metrics: Metrics::default(), executed: false };
                if event_tx.send(done).is_err() {
                    return;
                }
            }
            Err(_) => {
                let _ = event_tx.send(Event::WorkerExit { worker: i, unfinished: Some(batch), crashed: true });
                return;
            }
        }
    }
}

/// A pending job. Obtain the [`JobResult`] with [`JobHandle::wait`]; drop
/// the handle to fire-and-forget (the job still runs to completion).
pub struct JobHandle {
    id: u64,
    rx: Receiver<Result<JobResult>>,
}

impl JobHandle {
    /// The job id completions are routed by.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the job completes. A failed chunk resolves this to `Err`
    /// as soon as the failure is known, without waiting for the job's
    /// remaining chunks to drain.
    pub fn wait(self) -> Result<JobResult> {
        self.rx.recv().ok().context("scheduler exited without completing the job")?
    }

    /// Non-blocking poll: `None` while the job is still in flight.
    pub fn try_wait(&self) -> Option<Result<JobResult>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow!("scheduler exited without completing the job")))
            }
        }
    }

    /// Bounded wait: `None` if the job is still in flight when `timeout`
    /// expires — the handle stays usable, so a later `wait`/`wait_timeout`
    /// still delivers the result. This is what keeps admission-control and
    /// dead-bank tests (and impatient fleet callers) from hanging forever
    /// on a job that was genuinely lost.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<JobResult>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Err(anyhow!("scheduler exited without completing the job"))),
        }
    }
}

/// A cloneable, `Send` submission front-end: hand one to each client thread
/// to drive the same bank concurrently (the dispatcher multiplexes them).
#[derive(Clone)]
pub struct PimClient {
    cfg: ServiceConfig,
    event_tx: Sender<Event>,
    next_job: Arc<AtomicU64>,
    pending: Arc<AtomicU64>,
    live: Arc<AtomicUsize>,
}

impl PimClient {
    /// Jobs submitted to this bank but not yet resolved (completed or
    /// failed) — the queue-depth signal the fleet router places work by and
    /// admission control bounds.
    pub fn pending_jobs(&self) -> usize {
        self.pending.load(Ordering::SeqCst) as usize
    }

    /// Workers still alive in this bank. Zero means the bank is dead: every
    /// pending job has failed (or is about to) and new registrations are
    /// rejected — the fleet's cue to retire the bank and promote a spare.
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// The workload this bank serves (the fleet's routing key).
    pub fn kind(&self) -> WorkloadKind {
        self.cfg.kind
    }

    /// Submit a job as one typed [`Payload`] — the single submission path
    /// every tier funnels through ([`PimClient::submit`] and
    /// [`PimClient::submit_sort`] are one-line wrappers). `kind` names the
    /// workload the payload is meant for: it must be this bank's workload,
    /// and the payload's shape must match it — both mismatch directions
    /// resolve to the typed [`WorkloadMismatch`]. Non-blocking: returns a
    /// [`JobHandle`].
    pub fn submit_job(&self, kind: WorkloadKind, payload: Payload) -> Result<JobHandle> {
        let Some(shape) = payload.shape() else {
            bail!("fault-injection payloads cannot be submitted as jobs");
        };
        if kind != self.cfg.kind || shape != self.cfg.kind.shape() {
            return Err(anyhow::Error::new(WorkloadMismatch { service: self.cfg.kind, submitted: shape }));
        }
        ensure!(!payload.is_empty(), "empty job");
        let accum = match &payload {
            Payload::Pairs(p) => JobValues::Scalars(vec![0; p.len()]),
            Payload::Rows(r) => JobValues::Rows(vec![Vec::new(); r.len()]),
            Payload::States(s) => JobValues::States(vec![[0u64; 25]; s.len()]),
            Payload::Poison => unreachable!("poison rejected above"),
        };
        self.dispatch(accum, payload.chunked(self.cfg.rows))
    }

    /// Submit an element-wise job; returns immediately with a handle.
    pub fn submit(&self, a: &[u64], b: &[u64]) -> Result<JobHandle> {
        self.submit_job(self.cfg.kind, Payload::pairs(a, b)?)
    }

    /// Submit a sort job (one vector per crossbar row); returns immediately.
    pub fn submit_sort(&self, rows_data: &[Vec<u64>]) -> Result<JobHandle> {
        self.submit_job(self.cfg.kind, Payload::Rows(rows_data.to_vec()))
    }

    fn dispatch(&self, accum: JobValues, payloads: Vec<Payload>) -> Result<JobHandle> {
        let id = self.next_job.fetch_add(1, Ordering::Relaxed);
        let (result_tx, result_rx) = channel();
        let start = Instant::now();
        // Counted pending from the submit side (before the dispatcher even
        // registers it), so admission control never under-reads a burst.
        self.pending.fetch_add(1, Ordering::SeqCst);
        // The registration is enqueued before any chunk, so the dispatcher
        // always knows the job before its first completion can arrive.
        if self.event_tx.send(Event::Register { id, accum, n_chunks: payloads.len(), start, result_tx }).is_err() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            return Err(anyhow!("scheduler dispatcher exited"));
        }
        for (ci, payload) in payloads.into_iter().enumerate() {
            self.event_tx
                .send(Event::Enqueue(Segment { job: id, offset: ci * self.cfg.rows, payload, remaps: 0 }))
                .ok()
                .context("scheduler dispatcher exited")?;
        }
        Ok(JobHandle { id, rx: result_rx })
    }
}

/// A running PIM service: a bank of crossbar workers behind a concurrent,
/// fault-isolated scheduler. Submit jobs with [`PimService::submit`] (or
/// from many threads via [`PimService::client`]); shut down with
/// [`PimService::shutdown`] to retrieve aggregate statistics.
pub struct PimService {
    client: PimClient,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<ServiceStats>>,
    /// Bank-shared stuck-at map ([`PimService::inject_stuck`] writes it;
    /// every worker syncs it at batch boundaries).
    faults: Arc<Mutex<FaultMap>>,
    /// The bank's persistent wear + quarantine ledger (the dispatcher
    /// updates it; [`PimService::wear`] snapshots it).
    wear: Arc<Mutex<WearMap>>,
    /// Bank geometry (bounds-checks fault injection at the API edge).
    geom: Geometry,
    /// Cycles one full batch costs (for throughput reporting).
    pub batch_cycles: usize,
}

impl PimService {
    /// Start the bank: spawns `n_crossbars` worker threads, each owning one
    /// simulated crossbar with the compiled workload program, plus the
    /// dispatcher thread that schedules chunks and routes completions.
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        ensure!(cfg.n_crossbars >= 1, "need at least one crossbar");
        let geom = workload_geometry(cfg.kind, cfg.model, cfg.rows)?;
        let (event_tx, event_rx) = channel::<Event>();
        let stats = Arc::new(Mutex::new(ServiceStats::default()));
        let pending = Arc::new(AtomicU64::new(0));
        let live = Arc::new(AtomicUsize::new(cfg.n_crossbars));
        let faults = Arc::new(Mutex::new(FaultMap::new()));
        let wear = Arc::new(Mutex::new(WearMap::new(cfg.rows)));
        let mut first = Some(Worker::new(cfg.kind, cfg.model, geom)?);
        let batch_cycles = first.as_ref().expect("just built").batch_cycles();
        let mut ports = Vec::new();
        let mut workers = Vec::new();
        for i in 0..cfg.n_crossbars {
            let mut worker = match first.take() {
                Some(w) => w,
                None => Worker::new(cfg.kind, cfg.model, geom)?,
            };
            worker.set_replay(cfg.replay_mode, cfg.replay_threads);
            worker.set_fault_source(Arc::clone(&faults));
            let (tx, rx) = channel::<Batch>();
            let kill = Arc::new(AtomicBool::new(false));
            ports.push(WorkerPort { tx: Some(tx), kill: Arc::clone(&kill), alive: true, idle: false });
            let event_tx = event_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("pim-worker-{i}"))
                    .spawn(move || worker_loop(i, worker, rx, event_tx, kill))
                    .context("spawning worker thread")?,
            );
        }
        let dispatcher_stats = Arc::clone(&stats);
        let (dispatcher_pending, dispatcher_live) = (Arc::clone(&pending), Arc::clone(&live));
        let dispatcher_wear = Arc::clone(&wear);
        let dispatcher = std::thread::Builder::new()
            .name("pim-dispatcher".to_string())
            .spawn(move || {
                Dispatcher {
                    rx: event_rx,
                    ports,
                    coalescer: Coalescer::new(cfg.rows, cfg.linger, cfg.coalescing),
                    rows: cfg.rows,
                    jobs: HashMap::new(),
                    stats: dispatcher_stats,
                    wear: dispatcher_wear,
                    wear_leveling: cfg.wear_leveling,
                    max_remaps: cfg.max_remaps,
                    endurance_budget: cfg.endurance_budget,
                    started: Instant::now(),
                    pending: dispatcher_pending,
                    live: dispatcher_live,
                    shutting_down: false,
                }
                .run()
            })
            .context("spawning dispatcher thread")?;
        let client = PimClient { cfg, event_tx, next_job: Arc::new(AtomicU64::new(0)), pending, live };
        Ok(Self { client, dispatcher: Some(dispatcher), workers, stats, faults, wear, geom, batch_cycles })
    }

    /// A cloneable submission front-end for driving this bank from other
    /// threads. Clients outlive neither the jobs they submitted nor the
    /// service: once the service shuts down, their submissions fail cleanly.
    pub fn client(&self) -> PimClient {
        self.client.clone()
    }

    /// This service's configuration.
    pub fn config(&self) -> ServiceConfig {
        self.client.cfg
    }

    /// Submit a job as one typed [`Payload`] (see [`PimClient::submit_job`]
    /// — the single submission path; `submit`/`submit_sort` wrap it).
    pub fn submit_job(&self, kind: WorkloadKind, payload: Payload) -> Result<JobHandle> {
        self.client.submit_job(kind, payload)
    }

    /// Submit an element-wise job. Non-blocking: returns a [`JobHandle`];
    /// call [`JobHandle::wait`] for the classic blocking behavior.
    pub fn submit(&self, a: &[u64], b: &[u64]) -> Result<JobHandle> {
        self.client.submit(a, b)
    }

    /// Submit a sort job: each entry of `rows_data` is one vector to sort
    /// (one crossbar row). Non-blocking; the handle resolves to a
    /// [`JobResult`] whose values are the sorted per-row vectors.
    pub fn submit_sort(&self, rows_data: &[Vec<u64>]) -> Result<JobHandle> {
        self.client.submit_sort(rows_data)
    }

    /// Fault injection: abruptly retire worker `w`, as if its crossbar died.
    /// A chunk the worker had accepted but not yet executed is requeued to
    /// the surviving workers; jobs in flight complete unaffected (unless the
    /// bank is left empty, in which case they fail cleanly).
    pub fn kill_worker(&self, w: usize) -> Result<()> {
        ensure!(w < self.client.cfg.n_crossbars, "no worker {w} in a bank of {}", self.client.cfg.n_crossbars);
        self.client.event_tx.send(Event::KillWorker(w)).ok().context("scheduler dispatcher exited")
    }

    /// Fault injection: enqueue a poison segment that panics whichever
    /// worker picks it up — a crossbar dying mid-operation. Poison never
    /// co-batches with real traffic (the coalescer ships it alone), so the
    /// crash is contained: that worker retires, every job keeps its correct
    /// results.
    pub fn inject_worker_panic(&self) -> Result<()> {
        self.client
            .event_tx
            .send(Event::Enqueue(Segment { job: POISON_JOB, offset: 0, payload: Payload::Poison, remaps: 0 }))
            .ok()
            .context("scheduler dispatcher exited")
    }

    /// Fault injection: stick cell `(row, col)` of the bank at `value`,
    /// effective from the next batch boundary (every worker syncs the
    /// shared fault map before executing a batch). Coordinates are
    /// validated here, so a bad injection is an API error rather than a
    /// batch failure. Jobs in flight complete correctly: the dispatcher
    /// quarantines the row on first detection and remaps the affected
    /// segments onto healthy rows.
    pub fn inject_stuck(&self, row: usize, col: usize, value: bool) -> Result<()> {
        ensure!(row < self.geom.rows, "stuck row {row} outside the {}-row bank", self.geom.rows);
        ensure!(col < self.geom.n, "stuck column {col} outside the {}-column array", self.geom.n);
        self.faults.lock().unwrap_or_else(|e| e.into_inner()).faults.push(StuckAt { row, col, value });
        Ok(())
    }

    /// Snapshot of the bank's persistent wear map: per-row switch totals
    /// plus the stuck-at quarantine ledger.
    pub fn wear(&self) -> WearMap {
        self.wear.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Aggregate statistics so far.
    pub fn stats(&self) -> ServiceStats {
        *self.stats.lock().unwrap()
    }

    /// Jobs submitted but not yet resolved (see [`PimClient::pending_jobs`]).
    pub fn pending_jobs(&self) -> usize {
        self.client.pending_jobs()
    }

    /// Workers still alive in the bank (see [`PimClient::live_workers`]).
    pub fn live_workers(&self) -> usize {
        self.client.live_workers()
    }

    /// Stop the service and return the final statistics. Jobs still in
    /// flight are allowed to finish first.
    pub fn shutdown(mut self) -> ServiceStats {
        self.drain()
    }

    /// Non-consuming retire path: drain in-flight jobs, stop every thread,
    /// and return the final statistics, leaving the handle usable for
    /// stats-only reads. The fleet uses this to retire a bank held in a
    /// slot table (where ownership cannot be given up) — calling it twice
    /// is a no-op returning the same final statistics. Submissions after a
    /// drain fail cleanly ("service is shutting down").
    pub fn drain(&mut self) -> ServiceStats {
        self.finish();
        *self.stats.lock().unwrap()
    }

    fn finish(&mut self) {
        let _ = self.client.event_tx.send(Event::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for PimService {
    fn drop(&mut self) {
        // Best-effort: let the threads wind down without blocking the drop.
        let _ = self.client.event_tx.send(Event::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_end_to_end_multiply() {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 2,
            rows: 8,
            ..Default::default()
        })
        .unwrap();
        let a: Vec<u64> = (0..50).map(|i| 0x9e3779b9u64.wrapping_mul(i + 1) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..50).map(|i| 0x85ebca6bu64.wrapping_mul(i + 7) & 0xffff_ffff).collect();
        let res = svc.submit(&a, &b).unwrap().wait().unwrap();
        for i in 0..50 {
            assert_eq!(res.scalars()[i], a[i] * b[i], "element {i}");
        }
        assert!(res.control_bits > 0);
        let stats = svc.shutdown();
        assert_eq!(stats.jobs, 1);
        assert_eq!(stats.failed_jobs, 0);
        assert_eq!(stats.elements, 50);
        assert_eq!(stats.chunks, 7); // ceil(50 / 8)
        // One job alone cannot co-batch: six full batches plus the tail.
        assert_eq!(stats.batches, 7);
        assert_eq!(stats.occupied_rows, 50);
        assert_eq!(stats.capacity_rows, 56);
        assert!((stats.mean_occupancy() - 50.0 / 56.0).abs() < 1e-12);
    }

    #[test]
    fn service_multiple_jobs_accumulate_stats() {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Add32,
            model: ModelKind::Standard,
            n_crossbars: 3,
            rows: 4,
            ..Default::default()
        })
        .unwrap();
        for j in 0..5u64 {
            let a: Vec<u64> = (0..10).map(|i| i * 1000 + j).collect();
            let b: Vec<u64> = (0..10).map(|i| i + 42).collect();
            let res = svc.submit(&a, &b).unwrap().wait().unwrap();
            for i in 0..10usize {
                assert_eq!(res.scalars()[i], a[i] + b[i]);
            }
        }
        let stats = svc.shutdown();
        assert_eq!(stats.jobs, 5);
        assert_eq!(stats.elements, 50);
        assert!(stats.metrics.control_bits > 0);
    }

    /// Regression (the original wedge bug): an out-of-range operand used to
    /// panic the worker thread and leave `submit` blocked forever. It must
    /// fail only its own job, and the bank must keep serving.
    #[test]
    fn malformed_operand_fails_job_not_service() {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 2,
            rows: 4,
            ..Default::default()
        })
        .unwrap();
        let bad = svc.submit(&[1u64 << 33, 7], &[3, 5]).unwrap().wait();
        let err = format!("{:#}", bad.expect_err("oversized operand must fail the job"));
        assert!(err.contains("exceeds"), "unexpected error: {err}");

        // Same service, next job: every worker is still alive and correct.
        let a: Vec<u64> = (0..20).map(|i| i + 1).collect();
        let b: Vec<u64> = (0..20).map(|i| 2 * i + 3).collect();
        let res = svc.submit(&a, &b).unwrap().wait().expect("bank must keep serving after a bad job");
        for i in 0..20 {
            assert_eq!(res.scalars()[i], a[i] * b[i]);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.jobs, 1);
        assert_eq!(stats.failed_jobs, 1);
    }

    /// Two jobs genuinely in flight: the second (small) job is submitted
    /// after the first (large) one and completes while the first is still
    /// outstanding — impossible under the old one-job-at-a-time controller.
    #[test]
    fn jobs_overlap_and_complete_out_of_order() {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 2,
            rows: 4,
            ..Default::default()
        })
        .unwrap();
        let big_a: Vec<u64> = (0..64).map(|i| i + 1).collect();
        let big_b: Vec<u64> = (0..64).map(|i| i + 2).collect();
        let big = svc.submit(&big_a, &big_b).unwrap();
        let small = svc.submit(&[3, 4], &[5, 6]).unwrap();
        assert!(big.id() < small.id());

        // Wait for the later-submitted job first: completion routing by job
        // id makes the order irrelevant.
        let small_res = small.wait().unwrap();
        assert_eq!(small_res.scalars(), &[15, 24]);
        let big_res = big.wait().unwrap();
        for i in 0..64 {
            assert_eq!(big_res.scalars()[i], big_a[i] * big_b[i]);
        }
        let stats = svc.shutdown();
        assert_eq!(stats.jobs, 2);
        assert_eq!(stats.elements, 66);
    }

    /// The unified `submit_job(kind, payload)` path rejects shape and kind
    /// mismatches with typed errors, and the `try_*` value accessors return
    /// `ValueShapeMismatch` instead of panicking on the wrong shape.
    #[test]
    fn submit_job_rejects_mismatches_typed() {
        let svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 1,
            rows: 4,
            ..Default::default()
        })
        .unwrap();

        // Row-vector payload against an element-wise bank: typed mismatch.
        let err = svc.submit_job(WorkloadKind::Mul32, Payload::Rows(vec![vec![1, 2]])).expect_err("shape mismatch must be rejected");
        let typed = err.downcast_ref::<WorkloadMismatch>().expect("typed WorkloadMismatch");
        assert_eq!(typed.submitted, JobShape::RowVectors);

        // The sort wrapper goes through the same gate.
        let err = svc.submit_sort(&[vec![9, 1, 5]]).expect_err("sort on a multiply bank must be rejected");
        assert!(err.downcast_ref::<WorkloadMismatch>().is_some());

        // Poison is an internal control payload, never a job.
        assert!(svc.submit_job(WorkloadKind::Mul32, Payload::Poison).is_err());

        // A well-shaped job completes, and the typed accessors agree on shape.
        let res = svc.submit_job(WorkloadKind::Mul32, Payload::pairs(&[3], &[5]).unwrap()).unwrap().wait().unwrap();
        assert_eq!(res.try_scalars().unwrap(), &[15]);
        let shape_err = res.try_rows().expect_err("rows accessor on scalar values");
        assert_eq!(shape_err, ValueShapeMismatch { requested: JobShape::RowVectors, actual: JobShape::ElementWise });

        svc.shutdown();
    }
}
