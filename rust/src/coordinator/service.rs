//! The controller service: accepts vector jobs, batches elements onto
//! crossbar rows, dispatches chunks to worker threads, and aggregates
//! results plus architectural metrics.

use crate::coordinator::worker::{workload_geometry, Worker, WorkloadKind};
use crate::crossbar::crossbar::Metrics;
use crate::isa::models::ModelKind;
use anyhow::{ensure, Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Service configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    pub kind: WorkloadKind,
    pub model: ModelKind,
    /// Crossbars (= worker threads) in the bank.
    pub n_crossbars: usize,
    /// Rows per crossbar (elements per batch chunk).
    pub rows: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { kind: WorkloadKind::Mul32, model: ModelKind::Minimal, n_crossbars: 4, rows: 64 }
    }
}

/// Completed-job report.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub values: Vec<u64>,
    /// Simulated crossbar cycles spent on this job's chunks (summed).
    pub sim_cycles: u64,
    /// Control traffic the job generated, in bits.
    pub control_bits: u64,
    /// Wall-clock service latency.
    pub wall: std::time::Duration,
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServiceStats {
    pub jobs: u64,
    pub elements: u64,
    pub chunks: u64,
    pub metrics: Metrics,
}

/// A chunk's operand payload: scalar pairs for element-wise arithmetic,
/// per-row element vectors for sort jobs.
enum Payload {
    Pairs(Vec<(u64, u64)>),
    Rows(Vec<Vec<u64>>),
}

struct Chunk {
    job: u64,
    offset: usize,
    payload: Payload,
}

enum DoneValues {
    Scalars(Vec<u64>),
    Rows(Vec<Vec<u64>>),
}

struct ChunkDone {
    job: u64,
    offset: usize,
    values: DoneValues,
    metrics: Metrics,
}

/// A running PIM service: a bank of crossbar workers behind a batching
/// controller. Submit jobs with [`PimService::submit`]; shut down with
/// [`PimService::shutdown`] to retrieve aggregate statistics.
pub struct PimService {
    cfg: ServiceConfig,
    chunk_tx: Vec<Sender<Chunk>>,
    done_rx: Receiver<ChunkDone>,
    workers: Vec<JoinHandle<()>>,
    next_job: u64,
    next_worker: usize,
    stats: Arc<Mutex<ServiceStats>>,
    /// Cycles one full batch costs (for throughput reporting).
    pub batch_cycles: usize,
}

impl PimService {
    /// Start the bank: spawns `n_crossbars` worker threads, each owning one
    /// simulated crossbar with the compiled workload program.
    pub fn start(cfg: ServiceConfig) -> Result<Self> {
        ensure!(cfg.n_crossbars >= 1, "need at least one crossbar");
        let geom = workload_geometry(cfg.kind, cfg.model, cfg.rows);
        let (done_tx, done_rx) = channel::<ChunkDone>();
        let stats = Arc::new(Mutex::new(ServiceStats::default()));
        let mut chunk_tx = Vec::new();
        let mut workers = Vec::new();
        let probe = Worker::new(cfg.kind, cfg.model, geom)?;
        let batch_cycles = probe.batch_cycles();
        for _ in 0..cfg.n_crossbars {
            let (tx, rx) = channel::<Chunk>();
            chunk_tx.push(tx);
            let done_tx = done_tx.clone();
            let stats = Arc::clone(&stats);
            let mut worker = Worker::new(cfg.kind, cfg.model, geom)?;
            workers.push(std::thread::spawn(move || {
                while let Ok(chunk) = rx.recv() {
                    let (values, metrics, n) = match &chunk.payload {
                        Payload::Pairs(pairs) => {
                            let (v, m) = worker.run_batch(pairs).expect("workload program validated at compile time");
                            let n = v.len();
                            (DoneValues::Scalars(v), m, n)
                        }
                        Payload::Rows(rows_data) => {
                            let (v, m) = worker.run_sort_batch(rows_data).expect("workload program validated at compile time");
                            let n = v.len();
                            (DoneValues::Rows(v), m, n)
                        }
                    };
                    {
                        let mut s = stats.lock().unwrap();
                        s.chunks += 1;
                        s.elements += n as u64;
                        s.metrics.add(&metrics);
                    }
                    if done_tx.send(ChunkDone { job: chunk.job, offset: chunk.offset, values, metrics }).is_err() {
                        break;
                    }
                }
            }));
        }
        Ok(Self { cfg, chunk_tx, done_rx, workers, next_job: 0, next_worker: 0, stats, batch_cycles })
    }

    /// Submit an element-wise job and wait for its completion (the
    /// controller splits it into row-chunks spread across the bank).
    pub fn submit(&mut self, a: &[u64], b: &[u64]) -> Result<JobResult> {
        ensure!(a.len() == b.len(), "operand vectors differ in length");
        ensure!(!a.is_empty(), "empty job");
        let start = Instant::now();
        let id = self.next_job;
        self.next_job += 1;
        let mut outstanding = 0usize;
        for (ci, chunk) in a.chunks(self.cfg.rows).enumerate() {
            let offset = ci * self.cfg.rows;
            let pairs: Vec<(u64, u64)> = chunk.iter().zip(&b[offset..offset + chunk.len()]).map(|(&x, &y)| (x, y)).collect();
            let w = self.next_worker;
            self.next_worker = (self.next_worker + 1) % self.chunk_tx.len();
            self.chunk_tx[w].send(Chunk { job: id, offset, payload: Payload::Pairs(pairs) }).context("worker hung up")?;
            outstanding += 1;
        }
        let mut values = vec![0u64; a.len()];
        let mut sim_cycles = 0u64;
        let mut control_bits = 0u64;
        while outstanding > 0 {
            let done = self.done_rx.recv().context("workers hung up")?;
            ensure!(done.job == id, "out-of-order completion: job {} while waiting for {id}", done.job);
            let DoneValues::Scalars(vs) = done.values else {
                anyhow::bail!("scalar job received row results");
            };
            for (i, v) in vs.iter().enumerate() {
                values[done.offset + i] = *v;
            }
            sim_cycles += done.metrics.cycles;
            control_bits += done.metrics.control_bits;
            outstanding -= 1;
        }
        {
            let mut s = self.stats.lock().unwrap();
            s.jobs += 1;
        }
        Ok(JobResult { id, values, sim_cycles, control_bits, wall: start.elapsed() })
    }

    /// Submit a sort job: each entry of `rows_data` is one vector to sort
    /// (one crossbar row). Returns the sorted vectors.
    pub fn submit_sort(&mut self, rows_data: &[Vec<u64>]) -> Result<(Vec<Vec<u64>>, u64, u64)> {
        ensure!(self.cfg.kind == WorkloadKind::Sort16, "service is not a sort workload");
        ensure!(!rows_data.is_empty(), "empty job");
        let id = self.next_job;
        self.next_job += 1;
        let mut outstanding = 0usize;
        for (ci, chunk) in rows_data.chunks(self.cfg.rows).enumerate() {
            let w = self.next_worker;
            self.next_worker = (self.next_worker + 1) % self.chunk_tx.len();
            self.chunk_tx[w]
                .send(Chunk { job: id, offset: ci * self.cfg.rows, payload: Payload::Rows(chunk.to_vec()) })
                .context("worker hung up")?;
            outstanding += 1;
        }
        let mut values: Vec<Vec<u64>> = vec![Vec::new(); rows_data.len()];
        let mut sim_cycles = 0u64;
        let mut control_bits = 0u64;
        while outstanding > 0 {
            let done = self.done_rx.recv().context("workers hung up")?;
            ensure!(done.job == id, "out-of-order completion");
            let DoneValues::Rows(rows) = done.values else {
                anyhow::bail!("sort job received scalar results");
            };
            for (i, v) in rows.into_iter().enumerate() {
                values[done.offset + i] = v;
            }
            sim_cycles += done.metrics.cycles;
            control_bits += done.metrics.control_bits;
            outstanding -= 1;
        }
        self.stats.lock().unwrap().jobs += 1;
        Ok((values, sim_cycles, control_bits))
    }

    /// Aggregate statistics so far.
    pub fn stats(&self) -> ServiceStats {
        *self.stats.lock().unwrap()
    }

    /// Stop the workers and return the final statistics.
    pub fn shutdown(self) -> ServiceStats {
        drop(self.chunk_tx);
        for w in self.workers {
            let _ = w.join();
        }
        *self.stats.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_end_to_end_multiply() {
        let mut svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Mul32,
            model: ModelKind::Minimal,
            n_crossbars: 2,
            rows: 8,
        })
        .unwrap();
        let a: Vec<u64> = (0..50).map(|i| 0x9e3779b9u64.wrapping_mul(i + 1) & 0xffff_ffff).collect();
        let b: Vec<u64> = (0..50).map(|i| 0x85ebca6bu64.wrapping_mul(i + 7) & 0xffff_ffff).collect();
        let res = svc.submit(&a, &b).unwrap();
        for i in 0..50 {
            assert_eq!(res.values[i], a[i] * b[i], "element {i}");
        }
        assert!(res.control_bits > 0);
        let stats = svc.shutdown();
        assert_eq!(stats.jobs, 1);
        assert_eq!(stats.elements, 50);
        assert_eq!(stats.chunks, 7); // ceil(50 / 8)
    }

    #[test]
    fn service_multiple_jobs_accumulate_stats() {
        let mut svc = PimService::start(ServiceConfig {
            kind: WorkloadKind::Add32,
            model: ModelKind::Standard,
            n_crossbars: 3,
            rows: 4,
        })
        .unwrap();
        for j in 0..5u64 {
            let a: Vec<u64> = (0..10).map(|i| i * 1000 + j).collect();
            let b: Vec<u64> = (0..10).map(|i| i + 42).collect();
            let res = svc.submit(&a, &b).unwrap();
            for i in 0..10usize {
                assert_eq!(res.values[i], a[i] + b[i]);
            }
        }
        let stats = svc.shutdown();
        assert_eq!(stats.jobs, 5);
        assert_eq!(stats.elements, 50);
        assert!(stats.metrics.control_bits > 0);
    }
}
