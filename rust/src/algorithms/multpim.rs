//! MultPIM-style partitioned multiplication [14]: the paper's Section 5 case
//! study.
//!
//! One product bit-position per partition. Each iteration broadcasts one
//! multiplier bit to all partitions (log₂k cycles — MultPIM's logarithmic
//! broadcast), forms all partial-product bits at once, carry-save adds them
//! with a **parallel** full adder (one FA per partition per cycle), and
//! shifts the sum vector one partition down in constant time (MultPIM's
//! two-phase constant-time shift). A final serial pass resolves the
//! carry-save accumulator into the product's high half.
//!
//! Two variants:
//!
//! * [`MultPimVariant::Plain`] — every cycle is **minimal-model legal** by
//!   construction (uniform distance + periodic): double-NOT broadcast tree.
//! * [`MultPimVariant::Fast`] — single-NOT broadcast tree: each hop
//!   complements, so partitions end up holding `b` or `¬b` according to the
//!   parity of their tree depth (= popcount parity). The parity fix-up and
//!   partial-product cycles operate on *aperiodic* partition subsets —
//!   standard-legal, but **not** minimal-legal (they legalize into several
//!   periodic runs, reproducing the paper's standard→minimal latency gap).
//!   Under the unlimited model the scheduler ([`crate::isa` packer]) merges
//!   independent subset cycles with different intra indices, reproducing the
//!   unlimited→standard gap.

use crate::algorithms::program::{emit_fa_parallel, emit_fa_serial, Builder, FaIntra, Program};
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::operation::GateOp;
use anyhow::{ensure, Result};

/// Intra-partition column roles (identical in every partition — the paper's
/// *Identical Indices* criterion holds by construction).
pub mod intra {
    pub const A: usize = 0; // multiplicand bit a_j
    pub const NA: usize = 1; // ¬a_j (precomputed)
    pub const B: usize = 2; // multiplier bit b_j
    pub const BB: usize = 3; // broadcast slot
    pub const NB: usize = 4; // ¬broadcast (parity fix-up in Fast)
    pub const PP: usize = 5; // partial-product bit
    pub const S: usize = 6; // carry-save sum (weight i+j)
    pub const C: usize = 7; // carry (weight i+j)
    pub const SN: usize = 8; // new sum
    pub const CN: usize = 9; // new carry
    pub const T0: usize = 10; // FA scratch 10..=19
    pub const TS: usize = 20; // shift landing
    pub const TC: usize = 21; // carry-copy scratch
    pub const NP: usize = 22; // retired product bit, complemented
    // The epilog/final-add phases reuse columns that are dead once the main
    // loop ends — keeping the algorithmic area (Figure 6(c)) tight:
    pub const P: usize = PP; // product low bit p_j (PP dead after main loop)
    pub const H: usize = BB; // product high bit h_j (broadcast slot dead)
    pub const RT: usize = TS; // final-add carry-move scratch
    pub const R: usize = NB; // final-add running carry
    pub const RN: usize = TC; // final-add carry out
    pub const COLS: usize = 23;
}

/// Broadcast/partial-product strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MultPimVariant {
    /// Minimal-model-legal throughout (double-NOT broadcast).
    Plain,
    /// Single-NOT broadcast + parity fix-up (standard-legal).
    Fast,
}

/// A compiled partitioned multiplier.
#[derive(Debug, Clone)]
pub struct MultPim {
    pub program: Program,
    pub n_bits: usize,
    pub variant: MultPimVariant,
}

fn fa_intra() -> FaIntra {
    FaIntra {
        a: intra::S,
        b: intra::C,
        cin: intra::PP,
        s: intra::SN,
        cout: intra::CN,
        scratch: [10, 11, 12, 13, 14, 15, 16, 17, 18, 19],
    }
}

/// Build the MultPIM-style multiplier: `n_bits` must equal the partition
/// count `k` (one bit position per partition, as in MultPIM's evaluation:
/// 32-bit multiplication on 32 partitions).
pub fn build_multpim(geom: Geometry, variant: MultPimVariant) -> Result<MultPim> {
    let n = geom.k;
    ensure!(n >= 4, "need at least 4 partitions/bits");
    ensure!(geom.m() >= intra::COLS, "partition width {} below the {}-column MultPIM layout", geom.m(), intra::COLS);
    let k = geom.k;
    let lk = geom.log2_k();
    let all: Vec<usize> = (0..k).collect();
    let col = |p: usize, i: usize| geom.col(p, i);
    let across = |i: usize| -> Vec<usize> { (0..k).map(|p| col(p, i)).collect() };

    let mut b = Builder::new(geom, GateSet::NotNor);

    // ---- Prolog: NA = NOT(A); accumulators start at zero; NP slots ready.
    let mut init: Vec<usize> = across(intra::NA);
    init.extend(across(intra::NP));
    b.init1(init)?;
    b.concurrent(all.iter().map(|&p| GateOp::not(col(p, intra::A), col(p, intra::NA))).collect())?;
    let mut zeros = across(intra::S);
    zeros.extend(across(intra::C));
    b.init0(zeros)?;

    // Parity classes of the Fast broadcast tree.
    let even: Vec<usize> = (0..k).filter(|p| p.count_ones() % 2 == 0).collect();
    let odd: Vec<usize> = (0..k).filter(|p| p.count_ones() % 2 == 1).collect();

    // ---- Main loop: one iteration per multiplier bit.
    for i in 0..n {
        // Phase-1 initialization (single write cycle).
        let mut init: Vec<usize> = Vec::new();
        for &ix in &[intra::BB, intra::NB, intra::PP, intra::SN, intra::CN] {
            init.extend(across(ix));
        }
        for t in 0..10 {
            init.extend(across(intra::T0 + t));
        }
        if variant == MultPimVariant::Plain {
            init.extend(across(intra::TS));
        }
        b.init1(init)?;

        match variant {
            MultPimVariant::Plain => {
                // Fetch b_i into partition 0 (two NOTs via TS).
                b.not(col(i, intra::B), col(0, intra::TS))?;
                b.not(col(0, intra::TS), col(0, intra::BB))?;
                // Reverse-doubling broadcast, two NOTs per stage.
                for t in 0..lk {
                    let stride = k >> t;
                    let dist = k >> (t + 1);
                    let hop: Vec<GateOp> = (0..(1 << t))
                        .map(|j| GateOp::not(col(j * stride, intra::BB), col(j * stride + dist, intra::TS)))
                        .collect();
                    b.concurrent(hop)?;
                    let land: Vec<GateOp> = (0..(1 << t))
                        .map(|j| GateOp::not(col(j * stride + dist, intra::TS), col(j * stride + dist, intra::BB)))
                        .collect();
                    b.concurrent(land)?;
                }
                // NB = NOT(BB); PP = a AND b = NOR(NA, NB).
                b.concurrent(all.iter().map(|&p| GateOp::not(col(p, intra::BB), col(p, intra::NB))).collect())?;
                b.concurrent(all.iter().map(|&p| GateOp::nor(col(p, intra::NA), col(p, intra::NB), col(p, intra::PP))).collect())?;
            }
            MultPimVariant::Fast => {
                // Fetch ¬b_i into partition 0 with a single NOT.
                b.not(col(i, intra::B), col(0, intra::BB))?;
                // Single-NOT tree: each hop complements.
                for t in 0..lk {
                    let stride = k >> t;
                    let dist = k >> (t + 1);
                    let hop: Vec<GateOp> = (0..(1 << t))
                        .map(|j| GateOp::not(col(j * stride, intra::BB), col(j * stride + dist, intra::BB)))
                        .collect();
                    b.concurrent(hop)?;
                }
                // Even-parity partitions hold ¬b, odd hold b: fix up odd,
                // then form partial products per parity class. These subset
                // cycles are aperiodic — standard-legal, minimal-illegal.
                b.concurrent(odd.iter().map(|&p| GateOp::not(col(p, intra::BB), col(p, intra::NB))).collect())?;
                b.concurrent(even.iter().map(|&p| GateOp::nor(col(p, intra::NA), col(p, intra::BB), col(p, intra::PP))).collect())?;
                b.concurrent(odd.iter().map(|&p| GateOp::nor(col(p, intra::NA), col(p, intra::NB), col(p, intra::PP))).collect())?;
            }
        }

        // Carry-save add: (S, C, PP) -> SN, CN in every partition at once.
        emit_fa_parallel(&mut b, &all, fa_intra())?;

        // Phase-2 initialization: shift/copy targets (S and C re-init after
        // the FA consumed them).
        let mut init2: Vec<usize> = Vec::new();
        for &ix in &[intra::TC, intra::TS, intra::S, intra::C] {
            init2.extend(across(ix));
        }
        b.init1(init2)?;

        // Retire p_i = SN_0, stored complemented (resolved in the epilog).
        b.push(crate::isa::operation::Operation::serial(GateOp::not(col(0, intra::SN), col(i, intra::NP))))?;

        // Carry copy CN -> C (two in-place NOTs, all partitions).
        b.concurrent(all.iter().map(|&p| GateOp::not(col(p, intra::CN), col(p, intra::TC))).collect())?;
        b.concurrent(all.iter().map(|&p| GateOp::not(col(p, intra::TC), col(p, intra::C))).collect())?;

        // Constant-time shift S_j <- SN_{j+1} (MultPIM's two-phase shift):
        // odd sources, then even sources, then the parallel landing NOT.
        // TS_{k-1} keeps its init value 1, so S_{k-1} = NOT(1) = 0 shifts in.
        let phase_a: Vec<GateOp> = (1..k).step_by(2).map(|j| GateOp::not(col(j, intra::SN), col(j - 1, intra::TS))).collect();
        b.concurrent(phase_a)?;
        let phase_b: Vec<GateOp> = (2..k).step_by(2).map(|j| GateOp::not(col(j, intra::SN), col(j - 1, intra::TS))).collect();
        b.concurrent(phase_b)?;
        b.concurrent(all.iter().map(|&p| GateOp::not(col(p, intra::TS), col(p, intra::S))).collect())?;
    }

    // ---- Epilog: resolve retired complements into the product low half.
    b.init1(across(intra::P))?;
    b.concurrent(all.iter().map(|&p| GateOp::not(col(p, intra::NP), col(p, intra::P))).collect())?;

    // ---- Final add: high half H = S + C with a serial carry ripple.
    b.init0(vec![col(0, intra::R)])?;
    for j in 0..n {
        let mut init: Vec<usize> = (0..10).map(|t| col(j, intra::T0 + t)).collect();
        init.push(col(j, intra::H));
        init.push(col(j, intra::RN));
        b.init1(init)?;
        let scratch: Vec<usize> = (0..10).map(|t| col(j, intra::T0 + t)).collect();
        emit_fa_serial(&mut b, col(j, intra::S), col(j, intra::C), col(j, intra::R), col(j, intra::H), col(j, intra::RN), &scratch)?;
        if j + 1 < n {
            b.init1(vec![col(j + 1, intra::RT), col(j + 1, intra::R)])?;
            b.not(col(j, intra::RN), col(j + 1, intra::RT))?;
            b.not(col(j + 1, intra::RT), col(j + 1, intra::R))?;
        }
    }

    let name = match variant {
        MultPimVariant::Plain => format!("multpim{n}_plain"),
        MultPimVariant::Fast => format!("multpim{n}_fast"),
    };
    Ok(MultPim { program: b.finish(name), n_bits: n, variant })
}

impl MultPim {
    /// Load operands into `row` of a backend state image: bit `j` of each
    /// operand lands in partition `j` (MultPIM's strided layout).
    pub fn load(&self, state: &mut BitMatrix, row: usize, a: u64, bval: u64) -> Result<()> {
        ensure!(self.n_bits >= 64 || (a < 1 << self.n_bits && bval < 1 << self.n_bits), "operand exceeds {} bits", self.n_bits);
        let m = self.program.geom.m();
        state.write_strided(row, intra::A, m, self.n_bits, a)?;
        state.write_strided(row, intra::B, m, self.n_bits, bval)?;
        Ok(())
    }

    /// Read the 2N-bit product from `row`: low bits from the `P` stripe,
    /// high bits from the `H` stripe.
    pub fn read_product(&self, state: &BitMatrix, row: usize) -> Result<u64> {
        let m = self.program.geom.m();
        let lo = state.read_strided(row, intra::P, m, self.n_bits)?;
        let hi = state.read_strided(row, intra::H, m, self.n_bits)?;
        Ok(lo | (hi << self.n_bits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{ExecPipeline, PimBackend};
    use crate::crossbar::crossbar::Crossbar;
    use crate::isa::models::ModelKind;

    #[test]
    fn multiplies_exhaustive_4bit_both_variants() {
        let geom = Geometry::new(128, 4, 256).unwrap();
        for variant in [MultPimVariant::Plain, MultPimVariant::Fast] {
            let mult = build_multpim(geom, variant).unwrap();
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            let mut row = 0;
            for a in 0..16u64 {
                for b in 0..16u64 {
                    mult.load(&mut xb.state, row, a, b).unwrap();
                    row += 1;
                }
            }
            mult.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
            row = 0;
            for a in 0..16u64 {
                for b in 0..16u64 {
                    assert_eq!(mult.read_product(&xb.state, row).unwrap(), a * b, "{a}*{b} ({variant:?})");
                    row += 1;
                }
            }
        }
    }

    #[test]
    fn multiplies_random_8bit() {
        let geom = Geometry::new(256, 8, 64).unwrap();
        for variant in [MultPimVariant::Plain, MultPimVariant::Fast] {
            let mult = build_multpim(geom, variant).unwrap();
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            let mut expect = Vec::new();
            let mut seed = 7u64;
            for r in 0..64 {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                let a = (seed >> 33) & 0xff;
                let b = (seed >> 17) & 0xff;
                mult.load(&mut xb.state, r, a, b).unwrap();
                expect.push(a * b);
            }
            mult.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
            for r in 0..64 {
                assert_eq!(mult.read_product(&xb.state, r).unwrap(), expect[r], "row {r} ({variant:?})");
            }
        }
    }

    /// The Plain variant is minimal-model legal cycle-by-cycle; Fast is
    /// standard-legal but NOT minimal-legal (its parity subsets are
    /// aperiodic) — the paper's Section 5 structure.
    #[test]
    fn variant_model_legality() {
        let geom = Geometry::new(256, 8, 8).unwrap();
        let plain = build_multpim(geom, MultPimVariant::Plain).unwrap();
        plain.program.check_model(ModelKind::Minimal).unwrap();
        plain.program.check_model(ModelKind::Standard).unwrap();

        let fast = build_multpim(geom, MultPimVariant::Fast).unwrap();
        fast.program.check_model(ModelKind::Standard).unwrap();
        assert!(fast.program.check_model(ModelKind::Minimal).is_err());
    }

    /// Section 5 end-to-end: legalizing the (minimal-illegal) Fast variant
    /// into the minimal model must preserve the computed products, and the
    /// packed unlimited variant must too.
    #[test]
    fn legalized_and_packed_variants_still_multiply() {
        use crate::crossbar::gate::GateSet;
        use crate::isa::lower::LegalizeConfig;
        use crate::isa::schedule::pack_program;

        let geom = Geometry::new(256, 8, 16).unwrap();
        let fast = build_multpim(geom, MultPimVariant::Fast).unwrap();

        let (legal, stats) = fast.program.legalize(ModelKind::Minimal, &LegalizeConfig::default()).unwrap();
        assert!(stats.ops_out > stats.ops_in, "legalization must split aperiodic cycles");
        legal.check_model(ModelKind::Minimal).unwrap();

        let (packed, pstats) = pack_program(&fast.program.ops, ModelKind::Unlimited, &geom, GateSet::NotNor);
        assert!(pstats.merges > 0, "packer must find mergeable cycles");

        for (name, ops) in [("legalized", &legal.ops), ("packed", &packed)] {
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            let cases: Vec<(u64, u64)> = (0..16).map(|i| ((i * 31 + 4) % 256, (i * 57 + 9) % 256)).collect();
            for (r, &(a, b)) in cases.iter().enumerate() {
                fast.load(&mut xb.state, r, a, b).unwrap();
            }
            xb.execute_ops(ops).unwrap();
            for (r, &(a, b)) in cases.iter().enumerate() {
                assert_eq!(fast.read_product(&xb.state, r).unwrap(), a * b, "{name} row {r}");
            }
        }
    }

    /// The model programs executed through their *own* wire formats
    /// (encode → decode → periphery → execute), pre-encoded once and
    /// replayed — the coordinator's streaming path — still multiply
    /// correctly.
    #[test]
    fn all_models_multiply_via_messages() {
        for (model, variant) in [
            (ModelKind::Minimal, MultPimVariant::Plain),
            (ModelKind::Standard, MultPimVariant::Fast),
        ] {
            let geom = Geometry::new(256, 8, 8).unwrap();
            let mult = build_multpim(geom, variant).unwrap();
            let mut xb = Crossbar::new(geom, GateSet::NotNor);
            for r in 0..8u64 {
                mult.load(&mut xb.state, r as usize, 200 + r, 17 * r + 3).unwrap();
            }
            let mut pipe = ExecPipeline::wire(model, &mut xb);
            let prepared = mult.program.prepare(&mut pipe).unwrap();
            pipe.run_prepared(&prepared).unwrap();
            assert!(pipe.stats().control_bits > 0);
            drop(pipe);
            for r in 0..8u64 {
                assert_eq!(mult.read_product(&xb.state, r as usize).unwrap(), (200 + r) * (17 * r + 3), "{}", model.name());
            }
        }
    }

    /// Partitioned multiplication is O(N log N + N) cycles vs the serial
    /// baseline's O(N²): the speedup must grow with N.
    #[test]
    fn speedup_scales() {
        let g8 = Geometry::new(256, 8, 8).unwrap();
        let g16 = Geometry::new(512, 16, 8).unwrap();
        let par8 = build_multpim(g8, MultPimVariant::Plain).unwrap().program.stats().cycles;
        let par16 = build_multpim(g16, MultPimVariant::Plain).unwrap().program.stats().cycles;
        let ser8 = crate::algorithms::mult_serial::build_serial_multiplier(Geometry::new(256, 1, 8).unwrap(), 8).unwrap().program.stats().cycles;
        let ser16 = crate::algorithms::mult_serial::build_serial_multiplier(Geometry::new(512, 1, 8).unwrap(), 16).unwrap().program.stats().cycles;
        assert!((ser16 as f64 / par16 as f64) > (ser8 as f64 / par8 as f64), "speedup should grow with N");
    }
}
