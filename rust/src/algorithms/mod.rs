//! PIM algorithms as micro-op programs over the partitioned crossbar.
//!
//! * [`program`] — the program IR, row layouts, and the builder API.
//! * [`addition`] — NOR full adders and serial single-row N-bit addition.
//! * [`mult_serial`] — the optimized serial multiplier baseline (Section 5).
//! * [`multpim`] — the MultPIM-style partitioned multiplier [14]: one bit
//!   position per partition, log-time broadcast, constant-time shift,
//!   parallel carry-save full adders.
//! * [`sort`] — partitioned bitonic sorting (the paper's intro cites a 14×
//!   speedup with 16 partitions [1]).
//! * [`sha3`] — the HashPIM Keccak-f[1600] round program in the
//!   NOT/NOR/OR/XOR gate set, bit-sliced along z (one partition per lane
//!   bit), with the published 3,494-cycle round budget asserted in tests.

pub mod addition;
pub mod felix;
pub mod mult_serial;
pub mod multpim;
pub mod program;
pub mod sha3;
pub mod sort;

pub use program::{Program, ProgramStats};
