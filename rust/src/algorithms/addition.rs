//! Single-row N-bit addition (serial ripple-carry of NOR full adders).
//!
//! Every row of the crossbar adds its own pair of operands independently —
//! the throughput-oriented "single-row" style of [3, 18] the paper builds
//! on (experiment E11: ≈320 cycles for 32-bit addition in [18]; our NOR-only
//! 12-gate adder lands at `N·13 + 2` cycles).

use crate::algorithms::program::{emit_fa_serial, Builder, Program};
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use anyhow::{ensure, Result};

/// Column layout of the serial ripple adder within a row.
#[derive(Debug, Clone, Copy)]
pub struct AdderLayout {
    pub n_bits: usize,
    /// Operand A at columns `a0 .. a0+n`.
    pub a0: usize,
    /// Operand B.
    pub b0: usize,
    /// Sum (n+1 bits).
    pub s0: usize,
    /// Carry chain (n+1 columns; `carry0` is the constant-0 input).
    pub c0: usize,
    /// 10 scratch columns (reused across bit positions).
    pub scratch0: usize,
}

impl AdderLayout {
    /// Pack the adder at the start of the row.
    pub fn packed(n_bits: usize) -> Self {
        let a0 = 0;
        let b0 = a0 + n_bits;
        let s0 = b0 + n_bits;
        let c0 = s0 + n_bits + 1;
        let scratch0 = c0 + n_bits + 1;
        Self { n_bits, a0, b0, s0, c0, scratch0 }
    }

    /// Total columns consumed.
    pub fn width(&self) -> usize {
        self.scratch0 + 10
    }
}

/// A compiled adder: the program plus its layout for operand I/O.
#[derive(Debug, Clone)]
pub struct Adder {
    pub program: Program,
    pub layout: AdderLayout,
}

/// Build the serial single-row ripple adder.
pub fn build_adder(geom: Geometry, n_bits: usize) -> Result<Adder> {
    ensure!(n_bits >= 1 && n_bits <= 63, "n_bits {n_bits} out of range");
    let layout = AdderLayout::packed(n_bits);
    ensure!(layout.width() <= geom.n, "adder layout needs {} columns, crossbar has {}", layout.width(), geom.n);
    let mut b = Builder::new(geom, GateSet::NotNor);
    let scratch: Vec<usize> = (layout.scratch0..layout.scratch0 + 10).collect();

    // carry[0] = 0.
    b.init0(vec![layout.c0])?;
    for j in 0..n_bits {
        // Init scratch + this bit's outputs (one write cycle).
        let mut init = scratch.clone();
        init.push(layout.s0 + j);
        init.push(layout.c0 + j + 1);
        b.init1(init)?;
        emit_fa_serial(&mut b, layout.a0 + j, layout.b0 + j, layout.c0 + j, layout.s0 + j, layout.c0 + j + 1, &scratch)?;
    }
    // Final carry-out is the (n+1)-th sum bit: copy c[n] -> s[n].
    b.init1(vec![layout.s0 + n_bits, scratch[0]])?;
    b.not(layout.c0 + n_bits, scratch[0])?;
    b.not(scratch[0], layout.s0 + n_bits)?;
    Ok(Adder { program: b.finish(format!("add{n_bits}_serial")), layout })
}

impl Adder {
    /// Load operands into `row` of a backend state image.
    pub fn load(&self, state: &mut BitMatrix, row: usize, a: u64, bval: u64) -> Result<()> {
        ensure!(
            a < 1 << self.layout.n_bits && bval < 1 << self.layout.n_bits,
            "operand exceeds {} bits",
            self.layout.n_bits
        );
        state.write_field(row, self.layout.a0, self.layout.n_bits, a)?;
        state.write_field(row, self.layout.b0, self.layout.n_bits, bval)?;
        Ok(())
    }

    /// Read the (n+1)-bit sum from `row`.
    pub fn read_sum(&self, state: &BitMatrix, row: usize) -> Result<u64> {
        state.read_field(row, self.layout.s0, self.layout.n_bits + 1)
    }
}

// ---------------------------------------------------------------------------
// Partition-aligned adder
// ---------------------------------------------------------------------------

/// Per-bit column block of the partition-aligned adder. 16 columns per bit
/// position keeps every full-adder gate's *inputs* inside one partition
/// (the paper's *No Split-Input* criterion, footnote 3: "adjusting the
/// mapping algorithms") — only the carry output crosses into the next block.
const BLOCK: usize = 16;
const BA: usize = 0; // a_j
const BB_: usize = 1; // b_j
const BS: usize = 2; // s_j
const BCIN: usize = 3; // carry into position j
const BT: usize = 4; // 10 scratch columns, 4..14

/// A partition-aligned serial adder: encodable under **every** model
/// (baseline / unlimited / standard / minimal) because no gate has inputs
/// in two partitions.
#[derive(Debug, Clone)]
pub struct AlignedAdder {
    pub program: Program,
    pub n_bits: usize,
}

/// Build the aligned adder for a partitioned crossbar. Requires the
/// partition width to be a multiple of the 16-column bit block.
pub fn build_adder_aligned(geom: Geometry, n_bits: usize) -> Result<AlignedAdder> {
    ensure!(n_bits >= 1 && n_bits <= 63, "n_bits {n_bits} out of range");
    ensure!(geom.m() % BLOCK == 0, "partition width {} is not a multiple of the {BLOCK}-column bit block", geom.m());
    ensure!((n_bits + 1) * BLOCK <= geom.n, "aligned adder needs {} columns, crossbar has {}", (n_bits + 1) * BLOCK, geom.n);
    let off = |j: usize, c: usize| j * BLOCK + c;
    let mut b = Builder::new(geom, GateSet::NotNor);

    b.init0(vec![off(0, BCIN)])?;
    for j in 0..n_bits {
        let scratch: Vec<usize> = (0..10).map(|t| off(j, BT + t)).collect();
        let mut init = scratch.clone();
        init.push(off(j, BS));
        init.push(off(j + 1, BCIN));
        b.init1(init)?;
        emit_fa_serial(&mut b, off(j, BA), off(j, BB_), off(j, BCIN), off(j, BS), off(j + 1, BCIN), &scratch)?;
    }
    // Final carry-out becomes sum bit n.
    b.init1(vec![off(n_bits, BS), off(n_bits, BT)])?;
    b.not(off(n_bits, BCIN), off(n_bits, BT))?;
    b.not(off(n_bits, BT), off(n_bits, BS))?;
    Ok(AlignedAdder { program: b.finish(format!("add{n_bits}_aligned")), n_bits })
}

impl AlignedAdder {
    pub fn load(&self, state: &mut BitMatrix, row: usize, a: u64, bval: u64) -> Result<()> {
        ensure!(a < 1 << self.n_bits && bval < 1 << self.n_bits, "operand exceeds {} bits", self.n_bits);
        state.write_strided(row, BA, BLOCK, self.n_bits, a)?;
        state.write_strided(row, BB_, BLOCK, self.n_bits, bval)?;
        Ok(())
    }

    pub fn read_sum(&self, state: &BitMatrix, row: usize) -> Result<u64> {
        state.read_strided(row, BS, BLOCK, self.n_bits + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ExecPipeline;
    use crate::crossbar::crossbar::Crossbar;

    #[test]
    fn adds_exhaustive_4bit() {
        let geom = Geometry::new(128, 1, 256).unwrap();
        let adder = build_adder(geom, 4).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let mut row = 0;
        for a in 0..16u64 {
            for b in 0..16u64 {
                adder.load(&mut xb.state, row, a, b).unwrap();
                row += 1;
            }
        }
        adder.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        row = 0;
        for a in 0..16u64 {
            for b in 0..16u64 {
                assert_eq!(adder.read_sum(&xb.state, row).unwrap(), a + b, "{a}+{b}");
                row += 1;
            }
        }
    }

    #[test]
    fn adds_random_32bit_all_rows_in_parallel() {
        let geom = Geometry::new(256, 1, 64).unwrap();
        let adder = build_adder(geom, 32).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let mut expect = Vec::new();
        let mut seed = 0x12345678u64;
        for r in 0..64 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let a = seed >> 32;
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let b = seed >> 32;
            adder.load(&mut xb.state, r, a, b).unwrap();
            expect.push(a + b);
        }
        adder.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        for r in 0..64 {
            assert_eq!(adder.read_sum(&xb.state, r).unwrap(), expect[r], "row {r}");
        }
    }

    /// Oversized operands must be rejected at load, never silently
    /// truncated (they used to alias onto the low n bits).
    #[test]
    fn serial_adder_rejects_oversized_operands() {
        let geom = Geometry::new(256, 1, 4).unwrap();
        let adder = build_adder(geom, 32).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        assert!(adder.load(&mut xb.state, 0, 1 << 32, 1).is_err());
        assert!(adder.load(&mut xb.state, 0, 1, 1 << 32).is_err());
        adder.load(&mut xb.state, 0, u64::from(u32::MAX), u64::from(u32::MAX)).unwrap();
    }

    #[test]
    fn aligned_adder_rejects_oversized_operands() {
        let geom = Geometry::new(1024, 32, 4).unwrap();
        let adder = build_adder_aligned(geom, 32).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        assert!(adder.load(&mut xb.state, 0, 1 << 32, 1).is_err());
        assert!(adder.load(&mut xb.state, 0, 1, u64::MAX).is_err());
        adder.load(&mut xb.state, 0, u64::from(u32::MAX), 0).unwrap();
    }

    /// Experiment E11: the 32-bit serial adder's latency is in the
    /// few-hundred-cycle regime of [18] (320 cycles there; N·13+3 here).
    #[test]
    fn latency_matches_formula() {
        let geom = Geometry::new(1024, 1, 8).unwrap();
        let adder = build_adder(geom, 32).unwrap();
        let st = adder.program.stats();
        assert_eq!(st.cycles, 32 * 13 + 4);
        assert_eq!(st.gate_cycles, 32 * 12 + 2);
        assert!(st.cycles < 500, "serial addition should stay in the ~hundreds regime");
    }
}
