//! FELIX gate-set extension (footnote 2 of the paper: "the proposed designs
//! can be generalized to support additional types of gates (e.g., NAND,
//! OR), including gates with more than two inputs").
//!
//! With FELIX's single-cycle OR / NAND / AND / Minority3 [8], a full adder
//! shrinks from 12 NOT/NOR gates to 8:
//!
//! ```text
//! m    = Min3(a, b, cin)        // = NOT(carry-out)
//! cout = NOT(m)
//! w1   = OR(a, b)
//! w2   = OR(w1, cin)            // a ∨ b ∨ cin
//! w3   = AND(w2, m)             // (a∨b∨cin) ∧ ¬maj  — the "exactly one" term
//! ab   = AND(a, b)
//! abc  = AND(ab, cin)           // the "all three" term
//! s    = OR(w3, abc)
//! ```
//!
//! The extension keeps the paper's evaluation honest: all Figure 6 numbers
//! use the NOT/NOR set, and the extended control-message formats are
//! reported separately (see [`extended_message_bits`]).

use crate::algorithms::program::Builder;
use crate::crossbar::gate::{GateSet, GateType};
use crate::crossbar::geometry::Geometry;
use crate::isa::models::ModelKind;
use crate::isa::operation::GateOp;
use anyhow::{ensure, Result};

/// Emit the 8-gate FELIX full adder (serial). `scratch` needs 6 columns;
/// the caller initializes scratch + `s` + `cout` to 1.
pub fn emit_fa_felix(b: &mut Builder, a: usize, bb: usize, cin: usize, s: usize, cout: usize, scratch: &[usize]) -> Result<()> {
    ensure!(scratch.len() >= 6, "FELIX full adder needs 6 scratch columns");
    let (m, w1, w2, w3, ab, abc) = (scratch[0], scratch[1], scratch[2], scratch[3], scratch[4], scratch[5]);
    b.push(crate::isa::operation::Operation::serial(GateOp { gate: GateType::Min3, ins: vec![a, bb, cin], out: m }))?;
    b.push(crate::isa::operation::Operation::serial(GateOp::not(m, cout)))?;
    b.push(crate::isa::operation::Operation::serial(GateOp { gate: GateType::Or, ins: vec![a, bb], out: w1 }))?;
    b.push(crate::isa::operation::Operation::serial(GateOp { gate: GateType::Or, ins: vec![w1, cin], out: w2 }))?;
    b.push(crate::isa::operation::Operation::serial(GateOp { gate: GateType::And, ins: vec![w2, m], out: w3 }))?;
    b.push(crate::isa::operation::Operation::serial(GateOp { gate: GateType::And, ins: vec![a, bb], out: ab }))?;
    b.push(crate::isa::operation::Operation::serial(GateOp { gate: GateType::And, ins: vec![ab, cin], out: abc }))?;
    b.push(crate::isa::operation::Operation::serial(GateOp { gate: GateType::Or, ins: vec![w3, abc], out: s }))?;
    Ok(())
}

/// A FELIX serial ripple adder (the extension counterpart of
/// [`crate::algorithms::addition::build_adder`]): `N·9 + 2` cycles instead
/// of `N·13 + 2`.
#[derive(Debug, Clone)]
pub struct FelixAdder {
    pub program: crate::algorithms::program::Program,
    pub n_bits: usize,
    a0: usize,
    b0: usize,
    s0: usize,
}

pub fn build_adder_felix(geom: Geometry, n_bits: usize) -> Result<FelixAdder> {
    ensure!(n_bits >= 1 && n_bits <= 63, "n_bits out of range");
    let a0 = 0;
    let b0 = a0 + n_bits;
    let s0 = b0 + n_bits;
    let c0 = s0 + n_bits + 1;
    let scratch0 = c0 + n_bits + 1;
    ensure!(scratch0 + 6 <= geom.n, "FELIX adder needs {} columns", scratch0 + 6);
    let scratch: Vec<usize> = (scratch0..scratch0 + 6).collect();
    let mut b = Builder::new(geom, GateSet::Felix);

    b.init0(vec![c0])?;
    for j in 0..n_bits {
        let mut init = scratch.clone();
        init.push(s0 + j);
        init.push(c0 + j + 1);
        b.init1(init)?;
        emit_fa_felix(&mut b, a0 + j, b0 + j, c0 + j, s0 + j, c0 + j + 1, &scratch)?;
    }
    b.init1(vec![s0 + n_bits, scratch[0]])?;
    b.push(crate::isa::operation::Operation::serial(GateOp::not(c0 + n_bits, scratch[0])))?;
    b.push(crate::isa::operation::Operation::serial(GateOp::not(scratch[0], s0 + n_bits)))?;
    Ok(FelixAdder { program: b.finish(format!("add{n_bits}_felix")), n_bits, a0, b0, s0 })
}

impl FelixAdder {
    pub fn load(&self, state: &mut crate::crossbar::state::BitMatrix, row: usize, a: u64, bval: u64) -> Result<()> {
        ensure!(a < 1 << self.n_bits && bval < 1 << self.n_bits, "operand exceeds {} bits", self.n_bits);
        state.write_field(row, self.a0, self.n_bits, a)?;
        state.write_field(row, self.b0, self.n_bits, bval)?;
        Ok(())
    }

    pub fn read_sum(&self, state: &crate::crossbar::state::BitMatrix, row: usize) -> Result<u64> {
        state.read_field(row, self.s0, self.n_bits + 1)
    }
}

/// Extended control-message lengths for the FELIX gate set (footnote 2):
/// three input-index fields instead of two, plus a gate-type field of
/// `ceil(log2(6))  = 3` bits per *gate site* (per partition for unlimited,
/// shared for standard/minimal). Reported separately from the paper's
/// NOT/NOR numbers.
pub fn extended_message_bits(model: ModelKind, geom: &Geometry) -> usize {
    let (ln, lk, lm, k) = (geom.log2_n(), geom.log2_k(), geom.log2_m(), geom.k);
    let ty = 3; // ceil(log2(6)) gate types
    match model {
        ModelKind::Baseline => 4 * ln + ty,
        // 4 indices + 4 opcode bits (InA/InB/InC/Out) + type, per partition.
        ModelKind::Unlimited => k * (4 * lm + 4 + ty) + (k - 1),
        ModelKind::Standard => 4 * lm + ty + (2 * k - 1) + 1,
        ModelKind::Minimal => 4 * lm + ty + 3 * lk + lk + 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{ExecPipeline, PimBackend};
    use crate::crossbar::crossbar::Crossbar;

    #[test]
    fn felix_full_adder_truth_table() {
        let geom = Geometry::new(64, 1, 8).unwrap();
        let mut b = Builder::new(geom, GateSet::Felix);
        let scratch: Vec<usize> = (10..16).collect();
        let mut init = scratch.clone();
        init.extend([3, 4]);
        b.init1(init).unwrap();
        emit_fa_felix(&mut b, 0, 1, 2, 3, 4, &scratch).unwrap();
        let prog = b.finish("fa_felix");
        assert_eq!(prog.stats().gate_cycles, 8);

        let mut xb = Crossbar::new(geom, GateSet::Felix);
        for r in 0..8 {
            xb.state.set(r, 0, r & 1 == 1);
            xb.state.set(r, 1, r & 2 != 0);
            xb.state.set(r, 2, r & 4 != 0);
        }
        prog.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        for r in 0..8 {
            let total = (r & 1) + ((r >> 1) & 1) + ((r >> 2) & 1);
            assert_eq!(xb.state.get(r, 3), total & 1 == 1, "sum row {r}");
            assert_eq!(xb.state.get(r, 4), total >= 2, "cout row {r}");
        }
    }

    #[test]
    fn felix_adder_correct_and_faster() {
        let geom = Geometry::new(256, 1, 32).unwrap();
        let felix = build_adder_felix(geom, 16).unwrap();
        let notnor = crate::algorithms::addition::build_adder(geom, 16).unwrap();
        // ~30% fewer cycles.
        assert!(felix.program.stats().cycles < notnor.program.stats().cycles * 3 / 4);

        let mut xb = Crossbar::new(geom, GateSet::Felix);
        let mut expect = Vec::new();
        let mut seed = 5u64;
        for r in 0..32 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            let a = (seed >> 40) & 0xffff;
            let b = (seed >> 20) & 0xffff;
            felix.load(&mut xb.state, r, a, b).unwrap();
            expect.push(a + b);
        }
        felix.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        for r in 0..32 {
            assert_eq!(felix.read_sum(&xb.state, r).unwrap(), expect[r], "row {r}");
        }
    }

    /// Oversized operands must be rejected at load, never silently
    /// truncated (parity with `SerialMultiplier::load`).
    #[test]
    fn felix_adder_rejects_oversized_operands() {
        let geom = Geometry::new(256, 1, 8).unwrap();
        let felix = build_adder_felix(geom, 16).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::Felix);
        assert!(felix.load(&mut xb.state, 0, 1 << 16, 1).is_err());
        assert!(felix.load(&mut xb.state, 0, 1, 1 << 16).is_err());
        felix.load(&mut xb.state, 0, 0xffff, 0xffff).unwrap();
    }

    #[test]
    fn felix_rejected_on_notnor_crossbar() {
        let geom = Geometry::new(256, 1, 8).unwrap();
        let felix = build_adder_felix(geom, 8).unwrap();
        let mut strict = Crossbar::new(geom, GateSet::NotNor);
        assert!(strict.execute_ops(&felix.program.ops).is_err());
    }

    /// Extended formats stay ordered like the paper's: unlimited >> standard
    /// > minimal > baseline, and each costs more than its NOT/NOR original.
    #[test]
    fn extended_format_lengths() {
        let g = Geometry::paper(1).unwrap();
        let ext: Vec<usize> = ModelKind::ALL.iter().map(|&m| extended_message_bits(m, &g)).collect();
        let base: Vec<usize> = ModelKind::ALL.iter().map(|&m| crate::isa::encode::message_bits(m, &g)).collect();
        for (e, b) in ext.iter().zip(&base) {
            assert!(e > b);
        }
        // baseline, unlimited, standard, minimal
        assert!(ext[1] > ext[2] && ext[2] > ext[3] && ext[3] > ext[0] / 2);
    }
}
