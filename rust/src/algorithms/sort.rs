//! In-memory sorting (experiment E10: the paper's intro cites a 14× sorting
//! speedup with 16 partitions [1]).
//!
//! Bitonic sorting network over `k` elements per row, one element per
//! partition. Every compare-and-swap (CAS) stage executes all its pairs
//! concurrently: the copy-in, borrow-ripple comparison, select and copy-back
//! cycles each run as one semi-parallel operation across all pairs (uniform
//! distance = the stage's partner distance, identical intra indices). The
//! serial baseline executes the same network one CAS at a time in a
//! partition-free crossbar.

use crate::algorithms::program::{Builder, Program};
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::operation::GateOp;
use anyhow::{ensure, Result};

/// Intra-partition layout of the partitioned sorter (fits m ≥ 30).
mod ix {
    pub const X0: usize = 0; // element bits (w_bits wide)
    pub const YC0: usize = 8; // partner-element copy
    pub const NLT: usize = 17; // ¬(x < y)
    pub const TB: usize = 20; // cross-partition hop scratch
    pub const G0: usize = 21; // general scratch, 9 columns
}

/// A compiled sorter.
#[derive(Debug, Clone)]
pub struct Sorter {
    pub program: Program,
    pub n_elems: usize,
    pub w_bits: usize,
    /// Element base columns (one per element).
    elem_cols: Vec<usize>,
}

/// The bitonic network as (stage pairs, partner distance) lists:
/// `pairs[s] = (lo, hi, ascending)` with uniform `hi - lo` per stage.
fn bitonic_stages(n: usize) -> Vec<(usize, Vec<(usize, usize, bool)>)> {
    let mut stages = Vec::new();
    let mut kk = 2;
    while kk <= n {
        let mut jj = kk / 2;
        while jj >= 1 {
            let mut pairs = Vec::new();
            for i in 0..n {
                let partner = i ^ jj;
                if partner > i {
                    let asc = i & kk == 0;
                    pairs.push((i, partner, asc));
                }
            }
            stages.push((jj, pairs));
            jj /= 2;
        }
        kk *= 2;
    }
    stages
}

// ---------------------------------------------------------------------------
// Partitioned sorter
// ---------------------------------------------------------------------------

/// Build the partitioned sorter: sorts `k` elements of `w_bits` bits per row
/// (ascending), one element per partition.
pub fn build_sorter_partitioned(geom: Geometry, w_bits: usize) -> Result<Sorter> {
    let k = geom.k;
    ensure!(k >= 2, "need at least 2 partitions");
    ensure!(w_bits >= 2 && w_bits <= 8, "w_bits {w_bits} out of supported range 2..=8");
    ensure!(geom.m() >= 30, "partition width {} below the 30-column sorter layout", geom.m());
    let col = |p: usize, i: usize| geom.col(p, i);
    let g: Vec<usize> = (0..9).map(|t| ix::G0 + t).collect();
    let mut b = Builder::new(geom, GateSet::NotNor);

    for (d, pairs) in bitonic_stages(k) {
        let los: Vec<usize> = pairs.iter().map(|&(lo, _, _)| lo).collect();
        let his: Vec<usize> = pairs.iter().map(|&(_, hi, _)| hi).collect();

        // Cross-partition hops span the pair interval [lo, lo+d]; pairs whose
        // intervals interleave cannot fire in one cycle (sections must be
        // disjoint), so hops execute in `d` residue-class sub-phases —
        // physical serialization the partition model imposes on long-range
        // communication.
        let hop_groups: Vec<Vec<(usize, usize, bool)>> = (0..d)
            .map(|c| pairs.iter().copied().filter(|&(lo, _, _)| lo % (2 * d) == c).collect())
            .filter(|g: &Vec<_>| !g.is_empty())
            .collect();

        // 1. Copy partner elements into the lo partitions' YC region.
        b.init1(los.iter().flat_map(|&p| (0..w_bits).map(move |w| col(p, ix::YC0 + w))).collect())?;
        for w in 0..w_bits {
            b.init1(los.iter().map(|&p| col(p, ix::TB)).collect())?;
            for group in &hop_groups {
                b.concurrent(group.iter().map(|&(lo, hi, _)| GateOp::not(col(hi, ix::X0 + w), col(lo, ix::TB))).collect())?;
            }
            b.concurrent(los.iter().map(|&p| GateOp::not(col(p, ix::TB), col(p, ix::YC0 + w))).collect())?;
        }

        // 2. Borrow-ripple comparison in every lo partition concurrently:
        //    borrow' = maj(¬x_w, y_w, borrow);   lt = final borrow.
        // Borrow ping-pongs between G[7] and G[8].
        b.init0(los.iter().map(|&p| col(p, ix::G0 + 7)).collect())?;
        for w in 0..w_bits {
            let (br, brn) = if w % 2 == 0 { (g[7], g[8]) } else { (g[8], g[7]) };
            // init scratch + borrow-next.
            b.init1(los.iter().flat_map(|&p| [g[0], g[1], g[2], g[3], g[4], g[5], g[6], brn].into_iter().map(move |i| col(p, i))).collect())?;
            let each = |f: &dyn Fn(usize) -> GateOp| -> Vec<GateOp> { los.iter().map(|&p| f(p)).collect() };
            // a' = ¬x_w
            b.concurrent(each(&|p| GateOp::not(col(p, ix::X0 + w), col(p, g[0]))))?;
            // majority(a', y, br) via the FA carry network.
            b.concurrent(each(&|p| GateOp::nor(col(p, g[0]), col(p, ix::YC0 + w), col(p, g[1]))))?; // t1
            b.concurrent(each(&|p| GateOp::nor(col(p, g[0]), col(p, g[1]), col(p, g[2]))))?; // t2
            b.concurrent(each(&|p| GateOp::nor(col(p, ix::YC0 + w), col(p, g[1]), col(p, g[3]))))?; // t3
            b.concurrent(each(&|p| GateOp::nor(col(p, g[2]), col(p, g[3]), col(p, g[4]))))?; // xnor
            b.concurrent(each(&|p| GateOp::nor(col(p, g[4]), col(p, br), col(p, g[5]))))?; // u1
            b.concurrent(each(&|p| GateOp::nor(col(p, g[4]), col(p, g[5]), col(p, g[6]))))?; // u2 = (a'^y)·br
            // v2 = a'·y = NOR(t1, ¬xnor): reuse g[5] after u1 is consumed -> need fresh: use g[0] (a' no longer needed after t1..t3? a' used only for t1,t2 -> free), overwrite not allowed without init; instead:
            b.init1(los.iter().flat_map(|&p| [col(p, ix::TB)]).collect())?;
            b.concurrent(each(&|p| GateOp::not(col(p, g[4]), col(p, ix::TB))))?; // ¬xnor
            b.init1(los.iter().map(|&p| col(p, g[0])).collect())?;
            b.concurrent(each(&|p| GateOp::nor(col(p, g[1]), col(p, ix::TB), col(p, g[0]))))?; // v2 = a'·y
            b.init1(los.iter().map(|&p| col(p, g[1])).collect())?;
            b.concurrent(each(&|p| GateOp::nor(col(p, g[6]), col(p, g[0]), col(p, g[1]))))?; // ¬maj
            b.concurrent(each(&|p| GateOp::not(col(p, g[1]), col(p, brn))))?; // borrow'
        }
        let lt = if w_bits % 2 == 0 { g[7] } else { g[8] };
        // NLT = ¬lt.
        b.init1(los.iter().map(|&p| col(p, ix::NLT)).collect())?;
        b.concurrent(los.iter().map(|&p| GateOp::not(col(p, lt), col(p, ix::NLT))).collect())?;

        // 3. Select min/max per bit; write the kept element into X (lo) and
        //    stage the other into YC. Ascending pairs keep min at lo.
        for w in 0..w_bits {
            b.init1(los.iter().flat_map(|&p| [g[0], g[1], g[2], g[3], g[4], g[5], g[6], ix::TB].into_iter().map(move |i| col(p, i))).collect())?;
            let each = |f: &dyn Fn(usize) -> GateOp| -> Vec<GateOp> { los.iter().map(|&p| f(p)).collect() };
            b.concurrent(each(&|p| GateOp::not(col(p, ix::X0 + w), col(p, g[0]))))?; // ¬x
            b.concurrent(each(&|p| GateOp::not(col(p, ix::YC0 + w), col(p, g[1]))))?; // ¬y
            b.concurrent(each(&|p| GateOp::nor(col(p, g[0]), col(p, ix::NLT), col(p, g[2]))))?; // x·lt
            b.concurrent(each(&|p| GateOp::nor(col(p, g[1]), col(p, lt), col(p, g[3]))))?; // y·¬lt
            b.concurrent(each(&|p| GateOp::nor(col(p, g[2]), col(p, g[3]), col(p, g[4]))))?; // ¬min
            b.concurrent(each(&|p| GateOp::nor(col(p, g[0]), col(p, lt), col(p, g[5]))))?; // x·¬lt
            b.concurrent(each(&|p| GateOp::nor(col(p, g[1]), col(p, ix::NLT), col(p, g[6]))))?; // y·lt
            b.concurrent(each(&|p| GateOp::nor(col(p, g[5]), col(p, g[6]), col(p, ix::TB))))?; // ¬max
            b.init1(los.iter().flat_map(|&p| [col(p, ix::X0 + w), col(p, ix::YC0 + w)]).collect())?;
            // Ascending: X <- min, YC <- max. Descending: swapped.
            // (Two cycles: the kept element, then the staged partner —
            // both writes live in the same partition.)
            b.concurrent(
                pairs
                    .iter()
                    .map(|&(lo, _, up)| GateOp::not(col(lo, if up { g[4] } else { ix::TB }), col(lo, ix::X0 + w)))
                    .collect(),
            )?;
            b.concurrent(
                pairs
                    .iter()
                    .map(|&(lo, _, up)| GateOp::not(col(lo, if up { ix::TB } else { g[4] }), col(lo, ix::YC0 + w)))
                    .collect(),
            )?;
        }

        // 4. Copy the staged partner back to the hi partitions (same
        //    residue-class sub-phasing as the copy-in).
        for w in 0..w_bits {
            b.init1(his.iter().flat_map(|&p| [col(p, ix::TB), col(p, ix::X0 + w)]).collect())?;
            for group in &hop_groups {
                b.concurrent(group.iter().map(|&(lo, hi, _)| GateOp::not(col(lo, ix::YC0 + w), col(hi, ix::TB))).collect())?;
            }
            b.concurrent(his.iter().map(|&p| GateOp::not(col(p, ix::TB), col(p, ix::X0 + w))).collect())?;
        }
    }

    let elem_cols = (0..k).map(|p| col(p, ix::X0)).collect();
    Ok(Sorter { program: b.finish(format!("sort{k}x{w_bits}_partitioned")), n_elems: k, w_bits, elem_cols })
}

// ---------------------------------------------------------------------------
// Serial baseline
// ---------------------------------------------------------------------------

/// Build the serial sorter: the same bitonic network, one CAS at a time on a
/// partition-free crossbar. Elements live side-by-side in the row, so no
/// copy-in/copy-back cycles are needed — this is the *optimized* serial
/// baseline (mirroring the paper's optimized serial multiplier).
pub fn build_sorter_serial(geom: Geometry, n_elems: usize, w_bits: usize) -> Result<Sorter> {
    ensure!(n_elems.is_power_of_two() && n_elems >= 2, "element count must be a power of two");
    ensure!(w_bits >= 2 && w_bits <= 8, "w_bits {w_bits} out of supported range 2..=8");
    // Layout: elements at [e·w .. e·w+w), then scratch.
    let e0 = 0;
    let scratch0 = e0 + n_elems * w_bits;
    let g: Vec<usize> = (scratch0..scratch0 + 9).collect();
    let lt = scratch0 + 9;
    let nlt = scratch0 + 10;
    let nmin = scratch0 + 11;
    let nmax = scratch0 + 12;
    ensure!(nmax + 1 <= geom.n, "serial sorter needs {} columns", nmax + 1);
    let ecol = |e: usize, w: usize| e0 + e * w_bits + w;
    let mut b = Builder::new(geom, GateSet::NotNor);

    for (_, pairs) in bitonic_stages(n_elems) {
        for (lo, hi, asc) in pairs {
            // Borrow-ripple comparison x(lo) vs y(hi).
            b.init0(vec![g[7]])?;
            for w in 0..w_bits {
                let (br, brn) = if w % 2 == 0 { (g[7], g[8]) } else { (g[8], g[7]) };
                b.init1(vec![g[0], g[1], g[2], g[3], g[4], g[5], g[6], brn])?;
                b.not(ecol(lo, w), g[0])?;
                b.nor(g[0], ecol(hi, w), g[1])?;
                b.nor(g[0], g[1], g[2])?;
                b.nor(ecol(hi, w), g[1], g[3])?;
                b.nor(g[2], g[3], g[4])?;
                b.nor(g[4], br, g[5])?;
                b.nor(g[4], g[5], g[6])?;
                b.init1(vec![nmin])?;
                b.not(g[4], nmin)?; // ¬xnor (nmin reused as hop scratch)
                b.init1(vec![g[0]])?;
                b.nor(g[1], nmin, g[0])?; // v2
                b.init1(vec![g[1]])?;
                b.nor(g[6], g[0], g[1])?; // ¬maj
                b.not(g[1], brn)?;
            }
            let brf = if w_bits % 2 == 0 { g[7] } else { g[8] };
            b.init1(vec![lt, nlt])?;
            b.not(brf, nlt)?;
            b.not(nlt, lt)?;
            // Select + in-place writeback per bit.
            for w in 0..w_bits {
                b.init1(vec![g[0], g[1], g[2], g[3], g[4], g[5], nmin, nmax])?;
                b.not(ecol(lo, w), g[0])?;
                b.not(ecol(hi, w), g[1])?;
                b.nor(g[0], nlt, g[2])?;
                b.nor(g[1], lt, g[3])?;
                b.nor(g[2], g[3], nmin)?;
                b.nor(g[0], lt, g[4])?;
                b.nor(g[1], nlt, g[5])?;
                b.nor(g[4], g[5], nmax)?;
                b.init1(vec![ecol(lo, w), ecol(hi, w)])?;
                let (to_lo, to_hi) = if asc { (nmin, nmax) } else { (nmax, nmin) };
                b.not(to_lo, ecol(lo, w))?;
                b.not(to_hi, ecol(hi, w))?;
            }
        }
    }
    let elem_cols = (0..n_elems).map(|e| ecol(e, 0)).collect();
    Ok(Sorter { program: b.finish(format!("sort{n_elems}x{w_bits}_serial")), n_elems, w_bits, elem_cols })
}

impl Sorter {
    /// Load `values` (one per element slot) into `row` of a backend state
    /// image.
    pub fn load(&self, state: &mut BitMatrix, row: usize, values: &[u64]) -> Result<()> {
        ensure!(values.len() == self.n_elems, "expected {} values", self.n_elems);
        for (e, &v) in values.iter().enumerate() {
            ensure!(v < 1 << self.w_bits, "value {v} exceeds {} bits", self.w_bits);
            state.write_field(row, self.elem_cols[e], self.w_bits, v)?;
        }
        Ok(())
    }

    /// Read the element vector back from `row`.
    pub fn read(&self, state: &BitMatrix, row: usize) -> Result<Vec<u64>> {
        self.elem_cols.iter().map(|&c| state.read_field(row, c, self.w_bits)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ExecPipeline;
    use crate::crossbar::crossbar::Crossbar;

    fn lcg(seed: &mut u64) -> u64 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *seed >> 33
    }

    #[test]
    fn bitonic_network_shape() {
        let stages = bitonic_stages(16);
        assert_eq!(stages.len(), 10); // log(16)·(log(16)+1)/2
        let cas: usize = stages.iter().map(|(_, p)| p.len()).sum();
        assert_eq!(cas, 80);
        for (d, pairs) in &stages {
            for &(lo, hi, _) in pairs {
                assert_eq!(hi - lo, *d, "uniform distance per stage");
            }
        }
    }

    #[test]
    fn partitioned_sorts_random_rows() {
        let geom = Geometry::new(256, 8, 32).unwrap();
        let sorter = build_sorter_partitioned(geom, 6).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let mut seed = 77u64;
        let mut expect = Vec::new();
        for r in 0..32 {
            let vals: Vec<u64> = (0..8).map(|_| lcg(&mut seed) % 64).collect();
            sorter.load(&mut xb.state, r, &vals).unwrap();
            let mut s = vals.clone();
            s.sort_unstable();
            expect.push(s);
        }
        sorter.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        for r in 0..32 {
            assert_eq!(sorter.read(&xb.state, r).unwrap(), expect[r], "row {r}");
        }
    }

    #[test]
    fn serial_sorts_random_rows() {
        let geom = Geometry::new(128, 1, 16).unwrap();
        let sorter = build_sorter_serial(geom, 8, 6).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let mut seed = 123u64;
        let mut expect = Vec::new();
        for r in 0..16 {
            let vals: Vec<u64> = (0..8).map(|_| lcg(&mut seed) % 64).collect();
            sorter.load(&mut xb.state, r, &vals).unwrap();
            let mut s = vals.clone();
            s.sort_unstable();
            expect.push(s);
        }
        sorter.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        for r in 0..16 {
            assert_eq!(sorter.read(&xb.state, r).unwrap(), expect[r], "row {r}");
        }
    }

    /// E10 shape: the partitioned sorter must beat the serial baseline by a
    /// widening margin as the element count grows.
    #[test]
    fn partitioned_speedup() {
        let par = build_sorter_partitioned(Geometry::new(512, 16, 8).unwrap(), 6).unwrap();
        let ser = build_sorter_serial(Geometry::new(1024, 1, 8).unwrap(), 16, 6).unwrap();
        let sp = ser.program.stats().cycles as f64 / par.program.stats().cycles as f64;
        assert!(sp > 2.0, "16-element sort speedup {sp:.2} too small");
    }
}
