//! Program IR: a named sequence of operations plus the builder API the
//! algorithm constructors use, and per-program architectural statistics.
//!
//! Programs execute exclusively through
//! [`Program::execute`] / [`Program::prepare`] on an
//! [`ExecPipeline`] — one API for every backend and control path.

use crate::backend::{ExecPipeline, PimBackend, PreparedProgram};
use crate::crossbar::crossbar::init_message_bits;
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::isa::encode::message_bits_for;
use crate::isa::lower::{legalize_program, LegalizeConfig, LegalizeStats};
use crate::isa::models::ModelKind;
use crate::isa::operation::{GateOp, Operation};
use anyhow::{ensure, Result};

/// A compiled PIM program: one entry per simulated cycle.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub geom: Geometry,
    pub gate_set: GateSet,
    pub ops: Vec<Operation>,
    /// Columns ever read, written or initialized — the *algorithmic area*
    /// (memristor footprint per row) of Figure 6(c).
    pub used_cols: Vec<usize>,
}

/// Architectural cost summary of a program.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProgramStats {
    /// Latency in cycles (gate cycles + init cycles) — Figure 6(a).
    pub cycles: usize,
    pub gate_cycles: usize,
    pub init_cycles: usize,
    /// Total gates executed — the paper's energy proxy (Section 5.4).
    pub gates: usize,
    /// Memristors touched per row — Figure 6(c).
    pub footprint_cols: usize,
}

impl Program {
    pub fn stats(&self) -> ProgramStats {
        let mut s = ProgramStats { footprint_cols: self.used_cols.len(), ..Default::default() };
        for op in &self.ops {
            s.cycles += 1;
            match op {
                Operation::Init { .. } => s.init_cycles += 1,
                Operation::Gates(gs) => {
                    s.gate_cycles += 1;
                    s.gates += gs.len();
                }
            }
        }
        s
    }

    /// Control traffic (bits) to stream this program under `model`:
    /// gate cycles cost one model message each (including the per-cycle
    /// gate-type field when the gate set has more than one wire class),
    /// init cycles one write command each.
    pub fn control_bits(&self, model: ModelKind) -> u64 {
        let gate_msg = message_bits_for(model, &self.geom, self.gate_set) as u64;
        let init_msg = init_message_bits(&self.geom) as u64;
        self.ops
            .iter()
            .map(|op| match op {
                Operation::Init { .. } => init_msg,
                Operation::Gates(_) => gate_msg,
            })
            .sum()
    }

    /// Execute through a pipeline — the single execution API. The pipeline
    /// decides the path: [`ExecPipeline::direct`] runs abstract operations,
    /// [`ExecPipeline::wire`] streams bit-exact control messages through the
    /// periphery decode (the production path, with control-traffic
    /// metering), [`ExecPipeline::full`] legalizes first.
    pub fn execute(&self, pipe: &mut ExecPipeline<'_>) -> Result<()> {
        self.check_pipeline(pipe)?;
        pipe.run_ops(&self.ops)
    }

    /// Apply the pipeline's controller-side stages (legalize + encode) once,
    /// returning a stream that [`ExecPipeline::run_prepared`] can replay for
    /// every batch — the controller encodes a compiled program a single
    /// time (see DESIGN.md §Perf).
    pub fn prepare(&self, pipe: &mut ExecPipeline<'_>) -> Result<PreparedProgram> {
        self.check_pipeline(pipe)?;
        pipe.prepare(&self.ops)
    }

    fn check_pipeline(&self, pipe: &ExecPipeline<'_>) -> Result<()> {
        let geom = pipe.backend().geom();
        ensure!(
            geom == self.geom,
            "program '{}' was compiled for n={} k={} rows={}, but backend '{}' is n={} k={} rows={}",
            self.name,
            self.geom.n,
            self.geom.k,
            self.geom.rows,
            pipe.backend().name(),
            geom.n,
            geom.k,
            geom.rows
        );
        Ok(())
    }

    /// Rewrite into a `model`-legal program (Section 5's "alternatives").
    pub fn legalize(&self, model: ModelKind, cfg: &LegalizeConfig) -> Result<(Program, LegalizeStats)> {
        let (ops, stats) = legalize_program(&self.ops, model, &self.geom, self.gate_set, cfg)?;
        let mut p = Program {
            name: format!("{}@{}", self.name, model.name()),
            geom: self.geom,
            gate_set: self.gate_set,
            ops,
            used_cols: self.used_cols.clone(),
        };
        // Legalization may touch scratch columns; recompute the footprint.
        p.recompute_used();
        Ok((p, stats))
    }

    /// Verify every cycle is legal under `model`.
    pub fn check_model(&self, model: ModelKind) -> Result<()> {
        for (i, op) in self.ops.iter().enumerate() {
            model
                .check(op, &self.geom, self.gate_set)
                .map_err(|e| anyhow::anyhow!("cycle {i} of {} illegal under {}: {e}", self.name, model.name()))?;
        }
        Ok(())
    }

    fn recompute_used(&mut self) {
        let mut used = vec![false; self.geom.n];
        for op in &self.ops {
            match op {
                Operation::Init { cols, .. } => cols.iter().for_each(|&c| used[c] = true),
                Operation::Gates(gs) => {
                    for g in gs {
                        used[g.out] = true;
                        g.ins.iter().for_each(|&c| used[c] = true);
                    }
                }
            }
        }
        self.used_cols = used.iter().enumerate().filter_map(|(c, &u)| u.then_some(c)).collect();
    }
}

/// Incremental program constructor used by the algorithm builders.
#[derive(Debug, Clone)]
pub struct Builder {
    pub geom: Geometry,
    pub gate_set: GateSet,
    ops: Vec<Operation>,
    used: Vec<bool>,
    gates: usize,
}

impl Builder {
    pub fn new(geom: Geometry, gate_set: GateSet) -> Self {
        Self { geom, gate_set, ops: Vec::new(), used: vec![false; geom.n], gates: 0 }
    }

    /// Append a validated operation.
    pub fn push(&mut self, op: Operation) -> Result<()> {
        op.validate(&self.geom, self.gate_set)?;
        match &op {
            Operation::Init { cols, .. } => cols.iter().for_each(|&c| self.used[c] = true),
            Operation::Gates(gs) => {
                self.gates += gs.len();
                for g in gs {
                    self.used[g.out] = true;
                    g.ins.iter().for_each(|&c| self.used[c] = true);
                }
            }
        }
        self.ops.push(op);
        Ok(())
    }

    /// Serial two-input NOR.
    pub fn nor(&mut self, a: usize, b: usize, out: usize) -> Result<()> {
        self.push(Operation::serial(GateOp::nor(a, b, out)))
    }

    /// Serial NOT.
    pub fn not(&mut self, a: usize, out: usize) -> Result<()> {
        self.push(Operation::serial(GateOp::not(a, out)))
    }

    /// Concurrent gates (one cycle).
    pub fn concurrent(&mut self, gates: Vec<GateOp>) -> Result<()> {
        self.push(Operation::Gates(gates))
    }

    /// Initialization to logical one (the MAGIC gate precondition).
    pub fn init1(&mut self, cols: Vec<usize>) -> Result<()> {
        self.push(Operation::Init { cols, value: true })
    }

    /// Initialization to logical zero.
    pub fn init0(&mut self, cols: Vec<usize>) -> Result<()> {
        self.push(Operation::Init { cols, value: false })
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Stateful gates pushed so far (the per-step accounting the SHA-3
    /// builder reports against the published HashPIM table).
    pub fn gates(&self) -> usize {
        self.gates
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn finish(self, name: impl Into<String>) -> Program {
        let used_cols = self.used.iter().enumerate().filter_map(|(c, &u)| u.then_some(c)).collect();
        Program { name: name.into(), geom: self.geom, gate_set: self.gate_set, ops: self.ops, used_cols }
    }
}

/// Emit a 12-gate NOR/NOT full adder: `(s, cout) = a + b + cin`.
///
/// `scratch` must provide 10 distinct columns; the caller must have
/// initialized `scratch`, `s` and `cout` to logical one beforehand (batch
/// the inits — initialization is a single write cycle for any column set).
///
/// Gate derivation (all MAGIC NOT/NOR):
/// ```text
/// t1 = NOR(a,b)    t2 = NOR(a,t1)   t3 = NOR(b,t1)   x  = NOR(t2,t3)  // x = XNOR(a,b)
/// u1 = NOR(x,cin)  u2 = NOR(x,u1)   u3 = NOR(cin,u1) s  = NOR(u2,u3)  // sum
/// nx = NOT(x)      v2 = NOR(t1,nx)                                    // v2 = a·b
/// w  = NOR(u2,v2)  cout = NOT(w)                                      // u2 = (a⊕b)·cin
/// ```
pub fn emit_fa_serial(b: &mut Builder, a: usize, bb: usize, cin: usize, s: usize, cout: usize, scratch: &[usize]) -> Result<()> {
    anyhow::ensure!(scratch.len() >= 10, "full adder needs 10 scratch columns, got {}", scratch.len());
    let (t1, t2, t3, x, u1, u2, u3, nx, v2, w) =
        (scratch[0], scratch[1], scratch[2], scratch[3], scratch[4], scratch[5], scratch[6], scratch[7], scratch[8], scratch[9]);
    b.nor(a, bb, t1)?;
    b.nor(a, t1, t2)?;
    b.nor(bb, t1, t3)?;
    b.nor(t2, t3, x)?;
    b.nor(x, cin, u1)?;
    b.nor(x, u1, u2)?;
    b.nor(cin, u1, u3)?;
    b.nor(u2, u3, s)?;
    b.not(x, nx)?;
    b.nor(t1, nx, v2)?;
    b.nor(u2, v2, w)?;
    b.not(w, cout)?;
    Ok(())
}

/// Intra-partition column assignment for a partition-parallel full adder.
#[derive(Debug, Clone, Copy)]
pub struct FaIntra {
    pub a: usize,
    pub b: usize,
    pub cin: usize,
    pub s: usize,
    pub cout: usize,
    pub scratch: [usize; 10],
}

/// Emit the same 12-gate full adder with one gate **per partition per
/// cycle** (distance 0, period 1 — legal in every partition model).
/// Initialization of `s`, `cout` and scratch is the caller's job.
pub fn emit_fa_parallel(b: &mut Builder, partitions: &[usize], ix: FaIntra) -> Result<()> {
    let geom = b.geom;
    let seq: [(usize, usize, usize); 12] = [
        (ix.a, ix.b, ix.scratch[0]),
        (ix.a, ix.scratch[0], ix.scratch[1]),
        (ix.b, ix.scratch[0], ix.scratch[2]),
        (ix.scratch[1], ix.scratch[2], ix.scratch[3]),
        (ix.scratch[3], ix.cin, ix.scratch[4]),
        (ix.scratch[3], ix.scratch[4], ix.scratch[5]),
        (ix.cin, ix.scratch[4], ix.scratch[6]),
        (ix.scratch[5], ix.scratch[6], ix.s),
        (ix.scratch[3], ix.scratch[3], ix.scratch[7]), // NOT(x)
        (ix.scratch[0], ix.scratch[7], ix.scratch[8]),
        (ix.scratch[5], ix.scratch[8], ix.scratch[9]),
        (ix.scratch[9], ix.scratch[9], ix.cout), // NOT(w)
    ];
    for (ia, ib, io) in seq {
        let gates: Vec<GateOp> = partitions
            .iter()
            .map(|&p| {
                if ia == ib {
                    GateOp::not(geom.col(p, ia), geom.col(p, io))
                } else {
                    GateOp::nor(geom.col(p, ia), geom.col(p, ib), geom.col(p, io))
                }
            })
            .collect();
        b.concurrent(gates)?;
    }
    Ok(())
}

/// Columns a full adder's caller must initialize (scratch + outputs).
pub fn fa_init_intra(ix: &FaIntra) -> Vec<usize> {
    let mut v = ix.scratch.to_vec();
    v.push(ix.s);
    v.push(ix.cout);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::crossbar::Crossbar;

    #[test]
    fn serial_full_adder_truth_table() {
        let geom = Geometry::new(64, 1, 8).unwrap();
        // Columns: a=0, b=1, cin=2, s=3, cout=4, scratch=5..15.
        let scratch: Vec<usize> = (5..15).collect();
        let mut b = Builder::new(geom, GateSet::NotNor);
        let mut init = scratch.clone();
        init.extend([3, 4]);
        b.init1(init).unwrap();
        emit_fa_serial(&mut b, 0, 1, 2, 3, 4, &scratch).unwrap();
        let prog = b.finish("fa");

        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        // Rows 0..8 enumerate all (a, b, cin) combinations.
        for r in 0..8 {
            xb.state.set(r, 0, r & 1 == 1);
            xb.state.set(r, 1, r & 2 == 2);
            xb.state.set(r, 2, r & 4 == 4);
        }
        prog.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        for r in 0..8 {
            let total = (r & 1) + ((r >> 1) & 1) + ((r >> 2) & 1);
            assert_eq!(xb.state.get(r, 3), total & 1 == 1, "sum row {r}");
            assert_eq!(xb.state.get(r, 4), total >= 2, "cout row {r}");
        }
        let st = prog.stats();
        assert_eq!(st.gate_cycles, 12);
        assert_eq!(st.init_cycles, 1);
    }

    #[test]
    fn parallel_full_adder_matches_serial() {
        let geom = Geometry::new(256, 8, 64).unwrap();
        let ix = FaIntra { a: 0, b: 1, cin: 2, s: 3, cout: 4, scratch: [5, 6, 7, 8, 9, 10, 11, 12, 13, 14] };
        let parts: Vec<usize> = (0..8).collect();
        let mut b = Builder::new(geom, GateSet::NotNor);
        let init: Vec<usize> = parts.iter().flat_map(|&p| fa_init_intra(&ix).into_iter().map(move |i| geom.col(p, i))).collect();
        b.init1(init).unwrap();
        emit_fa_parallel(&mut b, &parts, ix).unwrap();
        let prog = b.finish("fa_par");
        // Every op must be minimal-legal (d=0, periodic T=1).
        prog.check_model(ModelKind::Minimal).unwrap();

        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(3);
        // Snapshot inputs.
        let mut inputs = vec![];
        for p in 0..8 {
            for r in 0..geom.rows {
                inputs.push((r, p, xb.state.get(r, geom.col(p, 0)), xb.state.get(r, geom.col(p, 1)), xb.state.get(r, geom.col(p, 2))));
            }
        }
        prog.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        for (r, p, a, bb, cin) in inputs {
            let total = a as u8 + bb as u8 + cin as u8;
            assert_eq!(xb.state.get(r, geom.col(p, 3)), total & 1 == 1, "s @ row {r} part {p}");
            assert_eq!(xb.state.get(r, geom.col(p, 4)), total >= 2, "cout @ row {r} part {p}");
        }
    }

    #[test]
    fn execute_rejects_geometry_mismatch() {
        let mut b = Builder::new(Geometry::new(64, 1, 8).unwrap(), GateSet::NotNor);
        b.init1(vec![0]).unwrap();
        let prog = b.finish("t");
        let mut xb = Crossbar::new(Geometry::new(128, 1, 8).unwrap(), GateSet::NotNor);
        assert!(prog.execute(&mut ExecPipeline::direct(&mut xb)).is_err());
        assert!(prog.prepare(&mut ExecPipeline::direct(&mut xb)).is_err());
    }

    #[test]
    fn control_bits_accounting() {
        let geom = Geometry::paper(8).unwrap();
        let mut b = Builder::new(geom, GateSet::NotNor);
        b.init1(vec![0, 1]).unwrap();
        b.nor(0, 1, 2).unwrap();
        let prog = b.finish("t");
        // init message (30) + minimal gate message (36).
        assert_eq!(prog.control_bits(ModelKind::Minimal), 30 + 36);
        assert_eq!(prog.control_bits(ModelKind::Unlimited), 30 + 607);
    }
}
