//! The optimized serial single-row multiplier — the baseline of Figure 6.
//!
//! Shift-add with a sliding accumulator: iteration `i` computes the partial
//! product `A · b_i` and ripple-adds it into the accumulator, writing each
//! full-adder sum one column "down" so the accumulator shift costs no
//! physical copies. One gate per cycle (no partitions needed): `O(N²)` gates
//! and cycles, as in [9].

use crate::algorithms::program::{emit_fa_serial, Builder, Program};
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use anyhow::{ensure, Result};

/// Column layout of the serial multiplier within a row.
#[derive(Debug, Clone, Copy)]
pub struct SerialMultLayout {
    pub n_bits: usize,
    pub a0: usize,
    pub b0: usize,
    /// Precomputed complements of A.
    pub na0: usize,
    /// Complement of the current multiplier bit (reused each iteration).
    pub nb: usize,
    /// Partial-product bit (reused for every position).
    pub pp: usize,
    /// Accumulator high half (N columns, rewritten every iteration).
    pub h0: usize,
    /// Carry chain (N+1 columns, rewritten every iteration).
    pub c0: usize,
    /// Product (2N columns).
    pub p0: usize,
    /// 10 scratch columns for the full adder.
    pub scratch0: usize,
}

impl SerialMultLayout {
    pub fn packed(n_bits: usize) -> Self {
        let a0 = 0;
        let b0 = a0 + n_bits;
        let na0 = b0 + n_bits;
        let nb = na0 + n_bits;
        let pp = nb + 1;
        let h0 = pp + 1;
        let c0 = h0 + n_bits;
        let p0 = c0 + n_bits + 1;
        let scratch0 = p0 + 2 * n_bits;
        Self { n_bits, a0, b0, na0, nb, pp, h0, c0, p0, scratch0 }
    }

    pub fn width(&self) -> usize {
        self.scratch0 + 10
    }
}

/// A compiled serial multiplier.
#[derive(Debug, Clone)]
pub struct SerialMultiplier {
    pub program: Program,
    pub layout: SerialMultLayout,
}

/// Build the optimized serial `n_bits × n_bits → 2·n_bits` multiplier.
pub fn build_serial_multiplier(geom: Geometry, n_bits: usize) -> Result<SerialMultiplier> {
    ensure!(n_bits >= 2 && n_bits <= 32, "n_bits {n_bits} out of range");
    let l = SerialMultLayout::packed(n_bits);
    ensure!(l.width() <= geom.n, "serial multiplier needs {} columns, crossbar has {}", l.width(), geom.n);
    let n = n_bits;
    let mut b = Builder::new(geom, GateSet::NotNor);
    let scratch: Vec<usize> = (l.scratch0..l.scratch0 + 10).collect();

    // Prolog: NA = NOT(A); accumulator (sliding, lives in h) starts at 0.
    b.init1((0..n).map(|j| l.na0 + j).collect())?;
    for j in 0..n {
        b.not(l.a0 + j, l.na0 + j)?;
    }
    let h_cols: Vec<usize> = (0..n).map(|j| l.h0 + j).collect();
    b.init0(h_cols)?;

    for i in 0..n {
        // nb = NOT(b_i); carry[0] = 0.
        b.init1(vec![l.nb])?;
        b.not(l.b0 + i, l.nb)?;
        b.init0(vec![l.c0])?;
        for j in 0..n {
            // FA position j: sum lands pre-shifted — j=0 retires directly to
            // the product, j>0 writes h[j-1] (already consumed by step j-1).
            let s_out = if j == 0 { l.p0 + i } else { l.h0 + j - 1 };
            let mut init = scratch.clone();
            init.extend([l.pp, s_out, l.c0 + j + 1]);
            b.init1(init)?;
            b.nor(l.na0 + j, l.nb, l.pp)?; // pp = a_j AND b_i
            emit_fa_serial(&mut b, l.h0 + j, l.pp, l.c0 + j, s_out, l.c0 + j + 1, &scratch)?;
        }
        // Top accumulator bit receives the final carry: h[n-1] = c[n].
        b.init1(vec![l.h0 + n - 1, scratch[0]])?;
        b.not(l.c0 + n, scratch[0])?;
        b.not(scratch[0], l.h0 + n - 1)?;
    }

    // Epilog: the accumulator holds the high half; copy h -> p[n..2n]
    // through double NOTs (scratch re-initialized between positions).
    b.init1((0..n).map(|j| l.p0 + n + j).collect())?;
    for j in 0..n {
        b.init1(vec![scratch[0]])?;
        b.not(l.h0 + j, scratch[0])?;
        b.not(scratch[0], l.p0 + n + j)?;
    }
    Ok(SerialMultiplier { program: b.finish(format!("mult{n}_serial")), layout: l })
}

impl SerialMultiplier {
    /// Load operands into `row` of a backend state image.
    pub fn load(&self, state: &mut BitMatrix, row: usize, a: u64, bval: u64) -> Result<()> {
        ensure!(a < 1 << self.layout.n_bits && bval < 1 << self.layout.n_bits, "operand exceeds {} bits", self.layout.n_bits);
        state.write_field(row, self.layout.a0, self.layout.n_bits, a)?;
        state.write_field(row, self.layout.b0, self.layout.n_bits, bval)?;
        Ok(())
    }

    /// Read the 2N-bit product from `row`.
    pub fn read_product(&self, state: &BitMatrix, row: usize) -> Result<u64> {
        state.read_field(row, self.layout.p0, 2 * self.layout.n_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ExecPipeline;
    use crate::crossbar::crossbar::Crossbar;

    #[test]
    fn multiplies_exhaustive_4bit() {
        let geom = Geometry::new(256, 1, 256).unwrap();
        let mult = build_serial_multiplier(geom, 4).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let mut row = 0;
        for a in 0..16u64 {
            for b in 0..16u64 {
                mult.load(&mut xb.state, row, a, b).unwrap();
                row += 1;
            }
        }
        mult.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        row = 0;
        for a in 0..16u64 {
            for b in 0..16u64 {
                assert_eq!(mult.read_product(&xb.state, row).unwrap(), a * b, "{a}*{b}");
                row += 1;
            }
        }
    }

    #[test]
    fn multiplies_random_8bit() {
        let geom = Geometry::new(256, 1, 64).unwrap();
        let mult = build_serial_multiplier(geom, 8).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let mut expect = Vec::new();
        let mut seed = 42u64;
        for r in 0..64 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            let a = (seed >> 33) & 0xff;
            let b = (seed >> 17) & 0xff;
            mult.load(&mut xb.state, r, a, b).unwrap();
            expect.push(a * b);
        }
        mult.program.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        for r in 0..64 {
            assert_eq!(mult.read_product(&xb.state, r).unwrap(), expect[r], "row {r}");
        }
    }

    /// The baseline is O(N²): ~14 cycles per bit-position per iteration.
    #[test]
    fn latency_is_quadratic() {
        let geom = Geometry::new(1024, 1, 8).unwrap();
        let m8 = build_serial_multiplier(geom, 8).unwrap().program.stats().cycles;
        let m16 = build_serial_multiplier(geom, 16).unwrap().program.stats().cycles;
        let ratio = m16 as f64 / m8 as f64;
        assert!(ratio > 3.0 && ratio < 5.0, "expected ~4x scaling, got {ratio} ({m8} -> {m16})");
    }
}
