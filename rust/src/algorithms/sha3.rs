//! SHA-3 (Keccak-f[1600]) over the partitioned crossbar — the HashPIM
//! workload [Oved et al.].
//!
//! ## Bit-slice layout
//!
//! The 5×5×64-bit Keccak state is mapped *bit-sliced along z*: partition
//! `z` (k = 64 partitions) holds bit `z` of every lane, and the intra-
//! partition column index names the lane slot. A lane is therefore a
//! 64-column stride-`m` field, and every lane-local step (Theta's column
//! parities, Chi, Pi) runs as one gate per partition — 64 state bits per
//! cycle — while the rotations of Rho and Theta's `rot1` become *partition
//! distance*: bit `z` of a lane rotated by `r` is a copy gate from
//! partition `z` into partition `(z + r) mod 64`.
//!
//! Intra-partition slot map (m = 64 columns per partition):
//!
//! ```text
//!   0..=24   A lanes (x + 5y)      — the state proper, round input/output
//!   25..=49  B lanes               — Theta/Pi staging (out ≠ in per cycle)
//!   50..=54  C[x] column parities  (Theta)
//!   55..=59  D[x] theta addends    (Theta)
//!   60..=62  S0/S1/S2 scratch
//! ```
//!
//! ## Rotation as grouped copies
//!
//! A rotate-left by `r` is emitted in the cheaper direction (`d = min(r,
//! 64-r)`): the non-wrapping copies all have uniform signed distance `±d`
//! and are grouped into cycles whose input partitions form arithmetic runs
//! of period `d + 1` — exactly the minimal control model's *Uniform
//! Partition-Distance* and *Periodic (T > d)* criteria, so every rotation
//! cycle is wire-representable by the range generator with no
//! legalization. The `d` wrapping bits cross in single-gate cycles (their
//! opposite direction cannot share a cycle with the main group). A copy is
//! `OR(a, a)` — single-cycle in the HashPIM NOT/NOR/OR/XOR gate set.
//!
//! Every cycle is *class-homogeneous* (all-XOR, all-OR, or all-NOT/NOR),
//! matching the one shared per-cycle gate-type field of the typed wire
//! formats (see [`crate::crossbar::gate::GateSet::wire_type_bits`]).
//!
//! The per-step cycle/gate budget is asserted against the published
//! HashPIM table (Theta 330 / Rho 2,911 / Pi 81 / Chi 140 / Iota 32 —
//! 3,494 cycles per round) in `tests/sha3_cycles.rs`; this mapping lands
//! well under it because the z-dimension bit-slice executes 64 state bits
//! per cycle and XOR is a native single-cycle gate here.

use crate::algorithms::program::{Builder, Program};
use crate::crossbar::gate::{GateSet, GateType};
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use anyhow::{ensure, Result};

/// Keccak lanes (5×5).
pub const LANES: usize = 25;
/// Lane width in bits = partitions of the SHA-3 geometry.
pub const LANE_BITS: usize = 64;
/// Keccak-f[1600] rounds.
pub const ROUNDS: usize = 24;

/// The published HashPIM per-round budget, `(step, cycles, gates)`: Theta
/// 330 / Rho 2,911 / Pi 81 / Chi 140 / Iota 32 cycles — 3,494 cycles and
/// 119,571 gates per round. `tests/sha3_cycles.rs` holds this mapping to
/// it step by step; `repro sha3` prints the comparison.
pub const PUBLISHED_STEP_TABLE: [(&str, usize, usize); 5] =
    [("theta", 330, 15_127), ("rho", 2_911, 82_300), ("pi", 81, 6_976), ("chi", 140, 14_720), ("iota", 32, 448)];
/// Published whole-round cycle count (sum of [`PUBLISHED_STEP_TABLE`]).
pub const PUBLISHED_ROUND_CYCLES: usize = 3_494;
/// Published whole-round gate count (sum of [`PUBLISHED_STEP_TABLE`]).
pub const PUBLISHED_ROUND_GATES: usize = 119_571;

// Intra-partition slot map.
const SLOT_B0: usize = LANES;
const SLOT_C0: usize = 2 * LANES;
const SLOT_D0: usize = 2 * LANES + 5;
const S0: usize = 2 * LANES + 10;
const S1: usize = S0 + 1;
const S2: usize = S0 + 2;

fn slot_a(lane: usize) -> usize {
    lane
}

fn slot_b(lane: usize) -> usize {
    SLOT_B0 + lane
}

// ---------------------------------------------------------------------------
// Reference semantics (the software oracle)
// ---------------------------------------------------------------------------

/// `rc(t)` of FIPS 202 §3.2.5: an LFSR over x⁸ + x⁶ + x⁵ + x⁴ + 1.
fn rc_bit(t: usize) -> bool {
    let mut r: u16 = 1;
    for _ in 0..t % 255 {
        r <<= 1;
        if r & 0x100 != 0 {
            r ^= 0x171;
        }
    }
    r & 1 == 1
}

/// The 24 Iota round constants, generated from the FIPS 202 LFSR (bit
/// `2ʲ - 1` of `RC[i]` is `rc(j + 7i)`).
pub fn round_constants() -> [u64; ROUNDS] {
    let mut rcs = [0u64; ROUNDS];
    for (ir, rc) in rcs.iter_mut().enumerate() {
        for j in 0..7 {
            if rc_bit(j + 7 * ir) {
                *rc |= 1u64 << ((1u32 << j) - 1);
            }
        }
    }
    rcs
}

/// The Rho rotation offsets `rho[x][y]`, generated from the FIPS 202
/// coordinate walk (`(x, y) ← (y, 2x + 3y)` starting at (1, 0), offset
/// `(t+1)(t+2)/2 mod 64`).
pub fn rho_offsets() -> [[usize; 5]; 5] {
    let mut rho = [[0usize; 5]; 5];
    let (mut x, mut y) = (1usize, 0usize);
    for t in 0..24 {
        rho[x][y] = ((t + 1) * (t + 2) / 2) % LANE_BITS;
        let (nx, ny) = (y, (2 * x + 3 * y) % 5);
        x = nx;
        y = ny;
    }
    rho
}

/// One software Keccak round on lane-indexed state (`a[x + 5y]`) — the
/// differential oracle the crossbar program is tested against.
pub fn keccak_round_sw(a: &mut [u64; LANES], rc: u64) {
    let rho = rho_offsets();
    // Theta
    let mut c = [0u64; 5];
    for x in 0..5 {
        c[x] = a[x] ^ a[x + 5] ^ a[x + 10] ^ a[x + 15] ^ a[x + 20];
    }
    for x in 0..5 {
        let d = c[(x + 4) % 5] ^ c[(x + 1) % 5].rotate_left(1);
        for y in 0..5 {
            a[x + 5 * y] ^= d;
        }
    }
    // Rho + Pi
    let mut b = [0u64; LANES];
    for y in 0..5 {
        for x in 0..5 {
            b[y + 5 * ((2 * x + 3 * y) % 5)] = a[x + 5 * y].rotate_left(rho[x][y] as u32);
        }
    }
    // Chi
    for y in 0..5 {
        for x in 0..5 {
            a[x + 5 * y] = b[x + 5 * y] ^ (!b[(x + 1) % 5 + 5 * y] & b[(x + 2) % 5 + 5 * y]);
        }
    }
    // Iota
    a[0] ^= rc;
}

/// The full software Keccak-f[1600] permutation (24 rounds).
pub fn keccak_f_sw(a: &mut [u64; LANES]) {
    let rcs = round_constants();
    for rc in rcs {
        keccak_round_sw(a, rc);
    }
}

// ---------------------------------------------------------------------------
// Crossbar program
// ---------------------------------------------------------------------------

/// Cycle / gate counts of one round step (the units of the published
/// HashPIM per-step table).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Sha3StepStats {
    pub cycles: usize,
    pub gates: usize,
}

/// Per-step accounting of one Keccak round as emitted.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Sha3RoundStats {
    pub theta: Sha3StepStats,
    pub rho: Sha3StepStats,
    pub pi: Sha3StepStats,
    pub chi: Sha3StepStats,
    pub iota: Sha3StepStats,
}

impl Sha3RoundStats {
    pub fn steps(&self) -> [(&'static str, Sha3StepStats); 5] {
        [("theta", self.theta), ("rho", self.rho), ("pi", self.pi), ("chi", self.chi), ("iota", self.iota)]
    }

    /// Whole-round totals (cycles include initialization writes, exactly as
    /// [`crate::algorithms::program::ProgramStats`] counts latency).
    pub fn total(&self) -> Sha3StepStats {
        let mut t = Sha3StepStats::default();
        for (_, s) in self.steps() {
            t.cycles += s.cycles;
            t.gates += s.gates;
        }
        t
    }
}

/// A compiled SHA-3 unit: the Keccak-f program plus the state loader /
/// reader for the bit-slice layout.
#[derive(Debug, Clone)]
pub struct Sha3Unit {
    pub program: Program,
    /// Per-round per-step accounting (identical for every round up to the
    /// Iota constant's init-mask split, so one representative is kept).
    pub round_stats: Sha3RoundStats,
    geom: Geometry,
}

impl Sha3Unit {
    /// Load one 25-lane state onto `row`: bit `z` of lane `i` lands at
    /// column `(z, slot_a(i))` — a stride-`m` field per lane.
    pub fn load(&self, state: &mut BitMatrix, row: usize, lanes: &[u64; LANES]) -> Result<()> {
        let m = self.geom.m();
        for (i, &lane) in lanes.iter().enumerate() {
            state.write_strided(row, slot_a(i), m, LANE_BITS, lane)?;
        }
        Ok(())
    }

    /// Read the permuted 25-lane state back from `row`.
    pub fn read(&self, state: &BitMatrix, row: usize) -> Result<[u64; LANES]> {
        let m = self.geom.m();
        let mut lanes = [0u64; LANES];
        for (i, lane) in lanes.iter_mut().enumerate() {
            *lane = state.read_strided(row, slot_a(i), m, LANE_BITS)?;
        }
        Ok(lanes)
    }
}

/// Validate a SHA-3 geometry: 64 partitions (one per z bit) of at least 63
/// columns (the slot map).
fn check_geom(geom: &Geometry) -> Result<()> {
    ensure!(geom.k == LANE_BITS, "SHA-3 bit-slice layout needs k = {LANE_BITS} partitions (one per lane bit), got k = {}", geom.k);
    ensure!(geom.m() > S2, "SHA-3 slot map needs {} columns per partition, got m = {}", S2 + 1, geom.m());
    Ok(())
}

/// `out = OR(a, a)` — the single-cycle copy of the HashPIM gate set.
fn copy_gate(src: usize, dst: usize) -> crate::isa::operation::GateOp {
    crate::isa::operation::GateOp { gate: GateType::Or, ins: vec![src, src], out: dst }
}

fn xor_gate(a: usize, b: usize, out: usize) -> crate::isa::operation::GateOp {
    crate::isa::operation::GateOp { gate: GateType::Xor, ins: vec![a, b], out }
}

/// One gate per partition (the 64-bits-per-cycle workhorse).
fn all_parts(b: &mut Builder, f: impl Fn(usize) -> crate::isa::operation::GateOp) -> Result<()> {
    let k = b.geom.k;
    b.concurrent((0..k).map(f).collect())
}

/// Initialize `slots` across every partition in one write cycle.
fn init_slots(b: &mut Builder, slots: &[usize]) -> Result<()> {
    let geom = b.geom;
    b.init1((0..geom.k).flat_map(|p| slots.iter().map(move |&s| geom.col(p, s))).collect())
}

/// Copy slot `src` rotated left by `r` lane-bit positions into slot `dst`:
/// partition `z`'s bit lands in partition `(z + r) mod 64`. Emits the
/// init + grouped-copy cycles described in the module docs (minimal-legal;
/// `2·min(r, 64-r) + 2` cycles, 64 gates).
fn emit_rotate_copy(b: &mut Builder, src: usize, dst: usize, r: usize) -> Result<()> {
    let geom = b.geom;
    let k = geom.k;
    let r = r % k;
    init_slots(b, &[dst])?;
    if r == 0 {
        return all_parts(b, |p| copy_gate(geom.col(p, src), geom.col(p, dst)));
    }
    let d = r.min(k - r);
    let forward = r <= k / 2; // rotate by distance +d, else by -d (≡ +r mod k)
    let dest = |z: usize| (z + r) % k;
    // Non-wrapping copies: uniform distance ±d; input partitions grouped
    // into arithmetic runs of period d+1 (> d ⇒ periodic, disjoint
    // sections).
    let main: Vec<usize> = if forward { (0..k - d).collect() } else { (d..k).collect() };
    for c in 0..(d + 1).min(main.len()) {
        let group: Vec<usize> = main.iter().copied().skip(c).step_by(d + 1).collect();
        b.concurrent(group.iter().map(|&z| copy_gate(geom.col(z, src), geom.col(dest(z), dst))).collect())?;
    }
    // Wrapping copies run against the main direction: one gate per cycle
    // (their span would interleave any grouped layout).
    let wrap: Vec<usize> = if forward { (k - d..k).collect() } else { (0..d).collect() };
    for z in wrap {
        b.concurrent(vec![copy_gate(geom.col(z, src), geom.col(dest(z), dst))])?;
    }
    Ok(())
}

/// Theta: `C[x] = ⊕_y A[x,y]`, `D[x] = C[x-1] ⊕ rot1(C[x+1])`,
/// `B[x,y] = A[x,y] ⊕ D[x]` (routed into the B slots — MAGIC-style gates
/// cannot write their own input column).
fn emit_theta(b: &mut Builder) -> Result<()> {
    let geom = b.geom;
    // Column parities, folded through scratch (XOR is 2-input).
    for x in 0..5 {
        let chain = [S0, S1, S2, SLOT_C0 + x];
        init_slots(b, &chain)?;
        let mut acc = slot_a(x);
        for (step, y) in (1..5).enumerate() {
            let lane = slot_a(x + 5 * y);
            all_parts(b, |p| xor_gate(geom.col(p, acc), geom.col(p, lane), geom.col(p, chain[step])))?;
            acc = chain[step];
        }
    }
    // D[x] = C[(x+4)%5] ⊕ rot1(C[(x+1)%5]).
    for x in 0..5 {
        emit_rotate_copy(b, SLOT_C0 + (x + 1) % 5, S0, 1)?;
        init_slots(b, &[SLOT_D0 + x])?;
        all_parts(b, |p| xor_gate(geom.col(p, SLOT_C0 + (x + 4) % 5), geom.col(p, S0), geom.col(p, SLOT_D0 + x)))?;
    }
    // Fold D into the state, staging into B.
    let b_slots: Vec<usize> = (0..LANES).map(slot_b).collect();
    init_slots(b, &b_slots)?;
    for lane in 0..LANES {
        let d_slot = SLOT_D0 + lane % 5;
        all_parts(b, |p| xor_gate(geom.col(p, slot_a(lane)), geom.col(p, d_slot), geom.col(p, slot_b(lane))))?;
    }
    Ok(())
}

/// Rho: rotate every B lane by its offset, landing back in the A slots.
fn emit_rho(b: &mut Builder) -> Result<()> {
    let rho = rho_offsets();
    for y in 0..5 {
        for x in 0..5 {
            let lane = x + 5 * y;
            emit_rotate_copy(b, slot_b(lane), slot_a(lane), rho[x][y])?;
        }
    }
    Ok(())
}

/// Pi: `B[y, 2x+3y] = A[x, y]` — pure lane permutation, distance-0 copies.
fn emit_pi(b: &mut Builder) -> Result<()> {
    let geom = b.geom;
    let b_slots: Vec<usize> = (0..LANES).map(slot_b).collect();
    init_slots(b, &b_slots)?;
    for y in 0..5 {
        for x in 0..5 {
            let src = slot_a(x + 5 * y);
            let dst = slot_b(y + 5 * ((2 * x + 3 * y) % 5));
            all_parts(b, |p| copy_gate(geom.col(p, src), geom.col(p, dst)))?;
        }
    }
    Ok(())
}

/// Chi: `A[x,y] = B[x,y] ⊕ (¬B[x+1,y] ∧ B[x+2,y])`, with the AND-NOT
/// factored for the gate set as `NOR(B[x+1,y], NOT B[x+2,y])`.
fn emit_chi(b: &mut Builder) -> Result<()> {
    let geom = b.geom;
    for y in 0..5 {
        for x in 0..5 {
            let dst = slot_a(x + 5 * y);
            let b0 = slot_b(x + 5 * y);
            let b1 = slot_b((x + 1) % 5 + 5 * y);
            let b2 = slot_b((x + 2) % 5 + 5 * y);
            init_slots(b, &[S0, S1, dst])?;
            all_parts(b, |p| crate::isa::operation::GateOp::not(geom.col(p, b2), geom.col(p, S0)))?;
            all_parts(b, |p| crate::isa::operation::GateOp::nor(geom.col(p, b1), geom.col(p, S0), geom.col(p, S1)))?;
            all_parts(b, |p| xor_gate(geom.col(p, b0), geom.col(p, S1), geom.col(p, dst)))?;
        }
    }
    Ok(())
}

/// Iota: `A[0,0] ^= RC`. The constant is materialized into a scratch slot
/// by two partition-masked write cycles (bit `z` of RC lives in partition
/// `z`), XORed with the lane into scratch, and copied back.
fn emit_iota(b: &mut Builder, rc: u64) -> Result<()> {
    let geom = b.geom;
    let k = geom.k;
    let ones: Vec<usize> = (0..k).filter(|&z| rc >> z & 1 == 1).map(|z| geom.col(z, S0)).collect();
    let zeros: Vec<usize> = (0..k).filter(|&z| rc >> z & 1 == 0).map(|z| geom.col(z, S0)).collect();
    if !ones.is_empty() {
        b.init1(ones)?;
    }
    if !zeros.is_empty() {
        b.init0(zeros)?;
    }
    init_slots(b, &[S1])?;
    all_parts(b, |p| xor_gate(geom.col(p, slot_a(0)), geom.col(p, S0), geom.col(p, S1)))?;
    init_slots(b, &[slot_a(0)])?;
    all_parts(b, |p| copy_gate(geom.col(p, S1), geom.col(p, slot_a(0))))
}

/// Cycle/gate delta of the builder since `mark` (a `(len, gates)` pair).
fn step_delta(b: &Builder, mark: (usize, usize)) -> Sha3StepStats {
    Sha3StepStats { cycles: b.len() - mark.0, gates: b.gates() - mark.1 }
}

/// Emit one full Keccak round (state in the A slots before and after),
/// returning the per-step cycle/gate accounting.
pub fn emit_keccak_round(b: &mut Builder, rc: u64) -> Result<Sha3RoundStats> {
    let mut stats = Sha3RoundStats::default();
    let mut mark = (b.len(), b.gates());
    emit_theta(b)?;
    stats.theta = step_delta(b, mark);
    mark = (b.len(), b.gates());
    emit_rho(b)?;
    stats.rho = step_delta(b, mark);
    mark = (b.len(), b.gates());
    emit_pi(b)?;
    stats.pi = step_delta(b, mark);
    mark = (b.len(), b.gates());
    emit_chi(b)?;
    stats.chi = step_delta(b, mark);
    mark = (b.len(), b.gates());
    emit_iota(b, rc)?;
    stats.iota = step_delta(b, mark);
    Ok(stats)
}

/// Build a single-round Keccak program (round 0) — the unit the published
/// per-step cycle table is asserted against.
pub fn build_keccak_round(geom: Geometry) -> Result<(Program, Sha3RoundStats)> {
    check_geom(&geom)?;
    let mut b = Builder::new(geom, GateSet::HashPim);
    let stats = emit_keccak_round(&mut b, round_constants()[0])?;
    Ok((b.finish("sha3_round"), stats))
}

/// Build the full 24-round Keccak-f[1600] permutation program.
pub fn build_keccak_f(geom: Geometry) -> Result<Sha3Unit> {
    check_geom(&geom)?;
    let mut b = Builder::new(geom, GateSet::HashPim);
    let mut round_stats = Sha3RoundStats::default();
    for rc in round_constants() {
        round_stats = emit_keccak_round(&mut b, rc)?;
    }
    Ok(Sha3Unit { program: b.finish("keccak_f1600"), round_stats, geom })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ExecPipeline;
    use crate::crossbar::crossbar::Crossbar;
    use crate::isa::models::ModelKind;

    fn geom() -> Geometry {
        Geometry::new(4096, 64, 4).unwrap()
    }

    #[test]
    fn generated_tables_match_fips() {
        let rc = round_constants();
        assert_eq!(rc[0], 0x0000000000000001);
        assert_eq!(rc[1], 0x0000000000008082);
        assert_eq!(rc[2], 0x800000000000808a);
        assert_eq!(rc[23], 0x8000000080008008);
        let rho = rho_offsets();
        assert_eq!(rho[0][0], 0);
        assert_eq!(rho[1][0], 1);
        assert_eq!(rho[2][0], 62);
        assert_eq!(rho[3][0], 28);
        assert_eq!(rho[4][0], 27);
        assert_eq!(rho[1][1], 44);
        assert_eq!(rho[2][2], 43);
    }

    /// The canonical Keccak-f[1600] known-answer: permuting the all-zero
    /// state yields lane 0 = F1258F7940E1DDE7 (XKCP test vectors).
    #[test]
    fn software_oracle_matches_known_answer() {
        let mut st = [0u64; LANES];
        keccak_f_sw(&mut st);
        assert_eq!(st[0], 0xF1258F7940E1DDE7);
        assert_ne!(st[24], 0, "permutation must diffuse into every lane");
    }

    #[test]
    fn single_round_program_matches_oracle() {
        let g = geom();
        let (prog, stats) = build_keccak_round(g).unwrap();
        assert!(stats.total().cycles <= 3494, "round exceeds the published HashPIM budget: {:?}", stats.total());
        let unit = Sha3Unit { program: prog.clone(), round_stats: stats, geom: g };
        let mut xb = Crossbar::new(g, GateSet::HashPim);
        let mut lanes = [0u64; LANES];
        for (i, l) in lanes.iter_mut().enumerate() {
            *l = 0x0123_4567_89ab_cdefu64.rotate_left(i as u32 * 7) ^ (i as u64);
        }
        unit.load(&mut xb.state, 1, &lanes).unwrap();
        prog.execute(&mut ExecPipeline::direct(&mut xb)).unwrap();
        let mut expect = lanes;
        keccak_round_sw(&mut expect, round_constants()[0]);
        assert_eq!(unit.read(&xb.state, 1).unwrap(), expect);
    }

    #[test]
    fn keccak_f_program_matches_oracle_on_wire_path() {
        let g = geom();
        let unit = build_keccak_f(g).unwrap();
        unit.program.check_model(ModelKind::Minimal).unwrap();
        unit.program.check_model(ModelKind::Standard).unwrap();
        let mut xb = Crossbar::new(g, GateSet::HashPim);
        let mut lanes = [0u64; LANES];
        for (i, l) in lanes.iter_mut().enumerate() {
            *l = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        }
        unit.load(&mut xb.state, 0, &lanes).unwrap();
        unit.program.execute(&mut ExecPipeline::wire(ModelKind::Minimal, &mut xb)).unwrap();
        let mut expect = lanes;
        keccak_f_sw(&mut expect);
        assert_eq!(unit.read(&xb.state, 0).unwrap(), expect);
    }

    #[test]
    fn rotation_copy_is_a_rotate_left() {
        let g = geom();
        for r in [0usize, 1, 2, 31, 32, 33, 62, 63] {
            let mut b = Builder::new(g, GateSet::HashPim);
            b.init1((0..g.k).map(|p| g.col(p, 0)).collect()).unwrap();
            emit_rotate_copy(&mut b, 0, 1, r).unwrap();
            let prog = b.finish("rot");
            prog.check_model(ModelKind::Minimal).unwrap();
            let mut xb = Crossbar::new(g, GateSet::HashPim);
            let v = 0xdead_beef_0bad_f00du64;
            xb.state.write_strided(0, 0, g.m(), LANE_BITS, v).unwrap();
            prog.execute(&mut ExecPipeline::wire(ModelKind::Minimal, &mut xb)).unwrap();
            assert_eq!(xb.state.read_strided(0, 1, g.m(), LANE_BITS).unwrap(), v.rotate_left(r as u32), "rot {r}");
        }
    }

    #[test]
    fn bad_geometry_rejected() {
        assert!(build_keccak_f(Geometry::new(1024, 32, 4).unwrap()).is_err(), "k != 64");
        assert!(build_keccak_round(Geometry::new(2048, 64, 4).unwrap()).is_err(), "m too narrow for the slot map");
    }
}
