//! The operation packer: merges adjacent independent cycles into one
//! semi-parallel cycle when the target model can express the combination.
//!
//! This is how the *unlimited* model earns its latency edge in Section 5:
//! cycles whose gates live in disjoint sections but use different
//! intra-partition indices (or mixed distances) can only execute together
//! under unlimited. Merging is semantics-preserving because concurrent gates
//! occupy disjoint sections — column sets cannot overlap, so no data hazard
//! can exist within a merged cycle.

use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::isa::models::ModelKind;
use crate::isa::operation::Operation;

/// Statistics of one packing run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PackStats {
    pub ops_in: usize,
    pub ops_out: usize,
    pub merges: usize,
}

/// Greedily merge adjacent `Gates` cycles while the combined cycle stays
/// physically valid (disjoint sections) and legal under `model`.
/// Initialization cycles act as barriers (writes cannot share a cycle with
/// stateful gates).
pub fn pack_program(ops: &[Operation], model: ModelKind, geom: &Geometry, gate_set: GateSet) -> (Vec<Operation>, PackStats) {
    let mut stats = PackStats { ops_in: ops.len(), ..Default::default() };
    let mut out: Vec<Operation> = Vec::with_capacity(ops.len());
    for op in ops {
        if let (Some(Operation::Gates(prev)), Operation::Gates(cur)) = (out.last(), op) {
            let mut merged = prev.clone();
            merged.extend(cur.iter().cloned());
            let cand = Operation::Gates(merged);
            // validate() guarantees disjoint sections => disjoint columns =>
            // merging two sequential cycles cannot change semantics.
            if cand.validate(geom, gate_set).is_ok() && model.supports(&cand, geom, gate_set) {
                *out.last_mut().unwrap() = cand;
                stats.merges += 1;
                continue;
            }
        }
        out.push(op.clone());
    }
    stats.ops_out = out.len();
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::PimBackend;
    use crate::crossbar::crossbar::Crossbar;
    use crate::isa::operation::GateOp;

    fn geom() -> Geometry {
        Geometry::new(256, 8, 32).unwrap()
    }

    #[test]
    fn merges_disjoint_cycles_under_unlimited() {
        let g = geom();
        // Two cycles with different intra indices in disjoint partitions:
        // only unlimited can merge them.
        let ops = vec![
            Operation::serial(GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 2))),
            Operation::serial(GateOp::nor(g.col(3, 4), g.col(3, 5), g.col(3, 6))),
        ];
        let (unl, s_unl) = pack_program(&ops, ModelKind::Unlimited, &g, GateSet::NotNor);
        assert_eq!(unl.len(), 1);
        assert_eq!(s_unl.merges, 1);
        let (std_, s_std) = pack_program(&ops, ModelKind::Standard, &g, GateSet::NotNor);
        assert_eq!(std_.len(), 2);
        assert_eq!(s_std.merges, 0);
    }

    #[test]
    fn never_merges_overlapping_sections() {
        let g = geom();
        // Second cycle reads the first one's output — sections overlap, so
        // the merge is rejected and semantics preserved.
        let ops = vec![
            Operation::serial(GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 2))),
            Operation::serial(GateOp::nor(g.col(0, 2), g.col(0, 3), g.col(0, 4))),
        ];
        let (packed, stats) = pack_program(&ops, ModelKind::Unlimited, &g, GateSet::NotNor);
        assert_eq!(packed.len(), 2);
        assert_eq!(stats.merges, 0);
    }

    #[test]
    fn init_cycles_are_barriers() {
        let g = geom();
        let ops = vec![
            Operation::serial(GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 2))),
            Operation::init1(vec![g.col(5, 0)]),
            Operation::serial(GateOp::nor(g.col(3, 4), g.col(3, 5), g.col(3, 6))),
        ];
        let (packed, _) = pack_program(&ops, ModelKind::Unlimited, &g, GateSet::NotNor);
        assert_eq!(packed.len(), 3);
    }

    #[test]
    fn packing_preserves_execution_semantics() {
        let g = geom();
        // A chain of independent cycles across different partitions.
        let ops: Vec<Operation> = (0..8)
            .map(|p| Operation::serial(GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 2 + p % 3))))
            .collect();
        let (packed, stats) = pack_program(&ops, ModelKind::Unlimited, &g, GateSet::NotNor);
        assert!(stats.merges > 0);

        let mut a = Crossbar::new(g, GateSet::NotNor);
        a.state.fill_random(11);
        let mut b = a.clone();
        a.execute_ops(&ops).unwrap();
        b.execute_ops(&packed).unwrap();
        assert_eq!(a.state, b.state);
        assert!(b.metrics.cycles < a.metrics.cycles);
    }
}
