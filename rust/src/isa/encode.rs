//! Bit-exact control-message codecs for every partition design.
//!
//! The controller⇄crossbar message is the paper's central practicality
//! concern. For `n = 1024`, `k = 32` (NOT/NOR gate set) the per-cycle gate
//! message lengths are:
//!
//! | design    | format                                            | bits |
//! |-----------|---------------------------------------------------|------|
//! | baseline  | `3·log2(n)`                                       | 30   |
//! | unlimited | `3k·log2(n/k) + 3k + (k-1)`                       | 607  |
//! | standard  | `3·log2(n/k) + (2k-1) + 1`                        | 79   |
//! | minimal   | `3·log2(n/k) + 3·log2(k) + log2(k) + 1`           | 36   |
//!
//! Encoding happens in the controller (`operation → Message → bits`),
//! decoding in the crossbar periphery (`bits → Message`, then
//! [`crate::periphery`] reconstructs the executed gates). Round-trip tests
//! assert `decode(encode(op)) ≡ op` for every model.
//!
//! Initialization writes travel on the ordinary write path and are *not*
//! part of these formats (the paper's formulas cover gate operations only);
//! the coordinator charges them one baseline-write message each — see
//! `DESIGN.md`.

use crate::crossbar::gate::{GateSet, GateType};
use crate::crossbar::geometry::Geometry;
use crate::isa::models::ModelKind;
use crate::isa::opcode::Opcode;
use crate::isa::operation::{Direction, GateOp, Operation};
use anyhow::{bail, ensure, Result};

// ---------------------------------------------------------------------------
// Bit-level message buffer
// ---------------------------------------------------------------------------

/// A fixed-width bit string (MSB-first within each pushed field), packed
/// into 64-bit words — this is wire traffic on the hot path, so pushes and
/// reads are word-wise shifts, not per-bool vector ops.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    pub fn new() -> Self {
        Self { words: Vec::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn mask(width: usize) -> u64 {
        if width >= 64 {
            !0
        } else {
            (1u64 << width) - 1
        }
    }

    /// Append `width` bits of `value` (MSB first). `width <= 64`.
    pub fn push_bits(&mut self, value: usize, width: usize) {
        debug_assert!(width <= 64);
        let mut remaining = width;
        let mut v = (value as u64) & Self::mask(width);
        while remaining > 0 {
            let bit_off = self.len % 64;
            if bit_off == 0 {
                self.words.push(0);
            }
            let space = 64 - bit_off;
            let take = remaining.min(space);
            // Highest `take` bits of the remaining value.
            let chunk = (v >> (remaining - take)) & Self::mask(take);
            let w = self.words.last_mut().unwrap();
            *w |= chunk << (space - take);
            v &= Self::mask(remaining - take);
            self.len += take;
            remaining -= take;
        }
    }

    pub fn push_bit(&mut self, b: bool) {
        self.push_bits(b as usize, 1);
    }

    /// Bit at position `i` (MSB-first order).
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (63 - i % 64)) & 1 == 1
    }

    /// Flip bit `i` — used by the fuzzing tests to corrupt wire traffic.
    pub fn flip(&mut self, i: usize) {
        assert!(i < self.len);
        self.words[i / 64] ^= 1u64 << (63 - i % 64);
    }
}

impl Default for BitVec {
    fn default() -> Self {
        Self::new()
    }
}

/// Sequential reader over a [`BitVec`].
pub struct BitReader<'a> {
    bv: &'a BitVec,
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(bv: &'a BitVec) -> Self {
        Self { bv, pos: 0 }
    }

    pub fn read_bits(&mut self, width: usize) -> Result<usize> {
        ensure!(self.pos + width <= self.bv.len, "message truncated: need {width} bits at offset {}", self.pos);
        let mut v = 0u64;
        let mut remaining = width;
        while remaining > 0 {
            let bit_off = self.pos % 64;
            let space = 64 - bit_off;
            let take = remaining.min(space);
            let word = self.bv.words[self.pos / 64];
            let chunk = (word >> (space - take)) & if take == 64 { !0 } else { (1u64 << take) - 1 };
            // take == 64 only on the first (aligned, full-word) chunk, where
            // v is still 0 — avoid the UB-adjacent 64-bit shift.
            v = if take == 64 { chunk } else { (v << take) | chunk };
            self.pos += take;
            remaining -= take;
        }
        Ok(v as usize)
    }

    pub fn read_bit(&mut self) -> Result<bool> {
        Ok(self.read_bits(1)? == 1)
    }

    pub fn finish(&self) -> Result<()> {
        ensure!(self.pos == self.bv.len, "trailing bits: consumed {} of {}", self.pos, self.bv.len);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Decoded message structure (what the periphery sees on its input pins)
// ---------------------------------------------------------------------------

/// Per-partition fields of an unlimited-model message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionFields {
    /// Intra-partition index fed to the `InA` decoder unit.
    pub ia: usize,
    /// Intra-partition index fed to the `InB` decoder unit.
    pub ib: usize,
    /// Intra-partition index fed to the `Out` decoder unit.
    pub io: usize,
    /// The half-gate opcode (Table 1).
    pub opcode: Opcode,
}

/// A decoded control message, one variant per design.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Message {
    /// Baseline crossbar: three absolute bitline indices.
    Baseline { ia: usize, ib: usize, io: usize },
    /// Unlimited: per-partition indices + opcodes, plus transistor selects
    /// (`true` = non-conducting / isolating).
    Unlimited { parts: Vec<PartitionFields>, selects: Vec<bool> },
    /// Standard: shared intra indices, per-partition enables, transistor
    /// selects, and the global direction bit.
    Standard { ia: usize, ib: usize, io: usize, enables: Vec<bool>, selects: Vec<bool>, dir: Direction },
    /// Minimal: shared intra indices, range-generator parameters
    /// (`p_start`, `p_end`, period `t`), partition distance, direction.
    Minimal { ia: usize, ib: usize, io: usize, p_start: usize, p_end: usize, t: usize, distance: usize, dir: Direction },
}

// ---------------------------------------------------------------------------
// Message lengths (the paper's formulas)
// ---------------------------------------------------------------------------

/// Gate-operation message length in bits for `model` on `geom` (NOT/NOR gate
/// set, as in the paper's evaluation).
pub fn message_bits(model: ModelKind, geom: &Geometry) -> usize {
    let (ln, lk, lm, k) = (geom.log2_n(), geom.log2_k(), geom.log2_m(), geom.k);
    match model {
        ModelKind::Baseline => 3 * ln,
        ModelKind::Unlimited => 3 * k * lm + 3 * k + (k - 1),
        ModelKind::Standard => 3 * lm + (2 * k - 1) + 1,
        ModelKind::Minimal => 3 * lm + 3 * lk + lk + 1,
    }
}

/// Gate-operation message length for `model` on `geom` under `gate_set`:
/// the paper's NOT/NOR format plus one shared per-cycle gate-type field of
/// [`GateSet::wire_type_bits`] bits. Zero extra bits for NOT/NOR, so the
/// published 30/607/79/36-bit formats are preserved exactly; the HashPIM
/// NOT/NOR/OR/XOR set pays 2 bits per message.
pub fn message_bits_for(model: ModelKind, geom: &Geometry, gate_set: GateSet) -> usize {
    message_bits(model, geom) + gate_set.wire_type_bits()
}

/// The shared *wire class* of a gate cycle under `gate_set` (NOT folds into
/// the NOR class). The gate-type field is one per message — like the shared
/// intra indices of the standard/minimal formats — so every gate in the
/// cycle must belong to the same class; mixed-class cycles have no encoding
/// and must be split by the scheduler.
pub fn cycle_wire_class(op: &Operation, gate_set: GateSet) -> Result<GateType> {
    let Operation::Gates(gates) = op else {
        bail!("initialization writes carry no gate-type field");
    };
    ensure!(!gates.is_empty(), "empty gate cycle has no wire class");
    let mut class: Option<GateType> = None;
    for g in gates {
        let c = gate_set
            .wire_class_of(g.gate)
            .ok_or_else(|| anyhow::anyhow!("gate {:?} is not wire-encodable under the {gate_set:?} gate set", g.gate))?;
        match class {
            None => class = Some(c),
            Some(prev) => {
                ensure!(prev == c, "mixed gate classes {prev:?} and {c:?} in one cycle: the per-cycle gate-type field encodes a single class");
            }
        }
    }
    Ok(class.expect("non-empty cycle"))
}

// ---------------------------------------------------------------------------
// Controller side: operation -> Message
// ---------------------------------------------------------------------------

/// Effective `(InA, InB)` columns of a gate: a NOT gate drives both input
/// decoder units with the same index (`NOR(a, a) = NOT(a)`), which is why the
/// paper's NOT/NOR message formats carry no gate-type field.
fn in_cols(g: &GateOp) -> Result<(usize, usize)> {
    match g.ins.len() {
        1 => Ok((g.ins[0], g.ins[0])),
        2 => Ok((g.ins[0], g.ins[1])),
        n => bail!("{n}-input gates are outside the paper's two-input message formats (footnote 2 generalization not encoded)"),
    }
}

/// Build the message a controller sends for `op` under `model`.
///
/// The operation must already be legal for the model
/// ([`ModelKind::check`]); initialization writes are rejected here — they
/// use the write path, not the gate-operation formats.
pub fn to_message(model: ModelKind, op: &Operation, geom: &Geometry) -> Result<Message> {
    let Operation::Gates(gates) = op else {
        bail!("initialization writes are not gate-operation messages");
    };
    // The controller encodes whatever the scheduler hands it, so malformed
    // operations must come back as `Err`, never panic the encoding thread.
    ensure!(!gates.is_empty(), "empty gate cycle cannot be encoded");
    match model {
        ModelKind::Baseline => {
            ensure!(gates.len() == 1, "baseline encodes a single gate");
            let g = &gates[0];
            let (a, b) = in_cols(g)?;
            Ok(Message::Baseline { ia: a, ib: b, io: g.out })
        }
        ModelKind::Unlimited => {
            let mut parts = vec![PartitionFields { ia: 0, ib: 0, io: 0, opcode: Opcode::IDLE }; geom.k];
            for g in gates {
                let (a, b) = in_cols(g)?;
                let (pa, pb, po) = (geom.partition_of(a), geom.partition_of(b), geom.partition_of(g.out));
                parts[pa].ia = geom.intra(a);
                parts[pa].opcode.in_a = true;
                parts[pb].ib = geom.intra(b);
                parts[pb].opcode.in_b = true;
                parts[po].io = geom.intra(g.out);
                parts[po].opcode.out = true;
            }
            Ok(Message::Unlimited { parts, selects: op.tight_selects(geom) })
        }
        ModelKind::Standard => {
            let g0 = &gates[0];
            let (a0, b0) = in_cols(g0)?;
            let (ia, ib, io) = (geom.intra(a0), geom.intra(b0), geom.intra(g0.out));
            let mut enables = vec![false; geom.k];
            for g in gates {
                let pi = g.input_partition(geom).ok_or_else(|| anyhow::anyhow!("split-input gate is not standard-legal"))?;
                enables[pi] = true;
                enables[geom.partition_of(g.out)] = true;
            }
            let dir = op.uniform_direction(geom)?.unwrap_or(Direction::InputsLeft);
            Ok(Message::Standard { ia, ib, io, enables, selects: op.tight_selects(geom), dir })
        }
        ModelKind::Minimal => {
            let g0 = &gates[0];
            let (a0, b0) = in_cols(g0)?;
            let (ia, ib, io) = (geom.intra(a0), geom.intra(b0), geom.intra(g0.out));
            let mut inputs: Vec<usize> = gates
                .iter()
                .map(|g| g.input_partition(geom).ok_or_else(|| anyhow::anyhow!("split-input gate is not minimal-legal")))
                .collect::<Result<_>>()?;
            inputs.sort_unstable();
            let distance = gates[0]
                .distance(geom)
                .ok_or_else(|| anyhow::anyhow!("split-input gate is not minimal-legal"))?
                .unsigned_abs();
            let dir = op.uniform_direction(geom)?.unwrap_or(Direction::InputsLeft);
            let (p_start, p_end) = (inputs[0], *inputs.last().unwrap());
            let t = if inputs.len() >= 2 { inputs[1] - inputs[0] } else { distance + 1 };
            ensure!(t >= 1 && t > distance || inputs.len() == 1, "period {t} must exceed distance {distance}");
            Ok(Message::Minimal { ia, ib, io, p_start, p_end, t, distance, dir })
        }
    }
}

/// Serialize a [`Message`] to its bit-exact wire format.
pub fn message_to_bits(msg: &Message, geom: &Geometry) -> BitVec {
    let mut bv = BitVec::new();
    write_message(&mut bv, msg, geom);
    bv
}

/// Append a [`Message`]'s wire bits to `bv` (shared by the NOT/NOR format
/// and the typed formats, which prefix a gate-type field).
fn write_message(bv: &mut BitVec, msg: &Message, geom: &Geometry) {
    let (ln, lk, lm) = (geom.log2_n(), geom.log2_k(), geom.log2_m());
    match msg {
        Message::Baseline { ia, ib, io } => {
            bv.push_bits(*ia, ln);
            bv.push_bits(*ib, ln);
            bv.push_bits(*io, ln);
        }
        Message::Unlimited { parts, selects } => {
            for p in parts {
                bv.push_bits(p.ia, lm);
                bv.push_bits(p.ib, lm);
                bv.push_bits(p.io, lm);
            }
            for p in parts {
                bv.push_bits(p.opcode.index() as usize, 3);
            }
            for &s in selects {
                bv.push_bit(s);
            }
        }
        Message::Standard { ia, ib, io, enables, selects, dir } => {
            bv.push_bits(*ia, lm);
            bv.push_bits(*ib, lm);
            bv.push_bits(*io, lm);
            for &e in enables {
                bv.push_bit(e);
            }
            for &s in selects {
                bv.push_bit(s);
            }
            bv.push_bit(matches!(dir, Direction::OutputsLeft));
        }
        Message::Minimal { ia, ib, io, p_start, p_end, t, distance, dir } => {
            bv.push_bits(*ia, lm);
            bv.push_bits(*ib, lm);
            bv.push_bits(*io, lm);
            bv.push_bits(*p_start, lk);
            bv.push_bits(*p_end, lk);
            bv.push_bits(*t - 1, lk); // T ∈ 1..=k encoded as T-1
            bv.push_bits(*distance, lk);
            bv.push_bit(matches!(dir, Direction::OutputsLeft));
        }
    }
}

/// Controller entry point: encode `op` for `model`. The result is exactly
/// [`message_bits`] long.
pub fn encode(model: ModelKind, op: &Operation, geom: &Geometry) -> Result<BitVec> {
    let msg = to_message(model, op, geom)?;
    let bv = message_to_bits(&msg, geom);
    debug_assert_eq!(bv.len(), message_bits(model, geom), "wire format length drifted from the paper formula");
    Ok(bv)
}

/// Controller entry point for an arbitrary gate set: the message of
/// [`encode`] prefixed with the shared per-cycle gate-type field. For
/// [`GateSet::NotNor`] the field is zero bits wide and the output is
/// bit-identical to [`encode`]; the result is exactly [`message_bits_for`]
/// long.
pub fn encode_with(model: ModelKind, op: &Operation, geom: &Geometry, gate_set: GateSet) -> Result<BitVec> {
    let class = cycle_wire_class(op, gate_set)?;
    let msg = to_message(model, op, geom)?;
    let mut bv = BitVec::new();
    let ty = gate_set.wire_type_bits();
    if ty > 0 {
        let idx = gate_set.wire_class_index(class).expect("cycle class came from this gate set");
        bv.push_bits(idx, ty);
    }
    write_message(&mut bv, &msg, geom);
    debug_assert_eq!(bv.len(), message_bits_for(model, geom, gate_set), "typed wire format length drifted");
    Ok(bv)
}

/// Crossbar-periphery entry point: parse the wire bits back into a
/// [`Message`]. Gate reconstruction happens in [`crate::periphery`].
pub fn decode(model: ModelKind, bits: &BitVec, geom: &Geometry) -> Result<Message> {
    ensure!(bits.len() == message_bits(model, geom), "wrong message length for {}: got {}, expected {}", model.name(), bits.len(), message_bits(model, geom));
    let mut r = BitReader::new(bits);
    let msg = read_message(&mut r, model, geom)?;
    r.finish()?;
    Ok(msg)
}

/// Periphery entry point for an arbitrary gate set: read the gate-type
/// field (if the set has one), then the model's message. Returns the wire
/// class alongside the message so [`crate::periphery::reconstruct_typed`]
/// knows which gate function to rebuild. Bit-identical to [`decode`] for
/// [`GateSet::NotNor`] (the class is then always NOR).
pub fn decode_with(model: ModelKind, bits: &BitVec, geom: &Geometry, gate_set: GateSet) -> Result<(GateType, Message)> {
    let expect = message_bits_for(model, geom, gate_set);
    ensure!(bits.len() == expect, "wrong message length for {} under {gate_set:?}: got {}, expected {expect}", model.name(), bits.len());
    let mut r = BitReader::new(bits);
    let ty = gate_set.wire_type_bits();
    let class = gate_set.wire_class_from_index(if ty > 0 { r.read_bits(ty)? } else { 0 })?;
    let msg = read_message(&mut r, model, geom)?;
    r.finish()?;
    Ok((class, msg))
}

/// Parse one message body (everything after any gate-type field) from `r`.
fn read_message(r: &mut BitReader<'_>, model: ModelKind, geom: &Geometry) -> Result<Message> {
    let (ln, lk, lm, k) = (geom.log2_n(), geom.log2_k(), geom.log2_m(), geom.k);
    let msg = match model {
        ModelKind::Baseline => {
            let ia = r.read_bits(ln)?;
            let ib = r.read_bits(ln)?;
            let io = r.read_bits(ln)?;
            Message::Baseline { ia, ib, io }
        }
        ModelKind::Unlimited => {
            let mut parts = vec![PartitionFields { ia: 0, ib: 0, io: 0, opcode: Opcode::IDLE }; k];
            for p in parts.iter_mut() {
                p.ia = r.read_bits(lm)?;
                p.ib = r.read_bits(lm)?;
                p.io = r.read_bits(lm)?;
            }
            for p in parts.iter_mut() {
                p.opcode = Opcode::from_index(r.read_bits(3)? as u8);
            }
            let selects = (0..k - 1).map(|_| r.read_bit()).collect::<Result<Vec<_>>>()?;
            Message::Unlimited { parts, selects }
        }
        ModelKind::Standard => {
            let ia = r.read_bits(lm)?;
            let ib = r.read_bits(lm)?;
            let io = r.read_bits(lm)?;
            let enables = (0..k).map(|_| r.read_bit()).collect::<Result<Vec<_>>>()?;
            let selects = (0..k - 1).map(|_| r.read_bit()).collect::<Result<Vec<_>>>()?;
            let dir = if r.read_bit()? { Direction::OutputsLeft } else { Direction::InputsLeft };
            Message::Standard { ia, ib, io, enables, selects, dir }
        }
        ModelKind::Minimal => {
            let ia = r.read_bits(lm)?;
            let ib = r.read_bits(lm)?;
            let io = r.read_bits(lm)?;
            let p_start = r.read_bits(lk)?;
            let p_end = r.read_bits(lk)?;
            let t = r.read_bits(lk)? + 1;
            let distance = r.read_bits(lk)?;
            let dir = if r.read_bit()? { Direction::OutputsLeft } else { Direction::InputsLeft };
            Message::Minimal { ia, ib, io, p_start, p_end, t, distance, dir }
        }
    };
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::gate::GateSet;

    fn paper_geom() -> Geometry {
        Geometry::paper(64).unwrap()
    }

    /// Section 5.2 / Figure 6(b): the exact message lengths.
    #[test]
    fn paper_message_lengths() {
        let g = paper_geom();
        assert_eq!(message_bits(ModelKind::Baseline, &g), 30);
        assert_eq!(message_bits(ModelKind::Unlimited, &g), 607);
        assert_eq!(message_bits(ModelKind::Standard, &g), 79);
        assert_eq!(message_bits(ModelKind::Minimal, &g), 36);
    }

    #[test]
    fn bitvec_roundtrip() {
        let mut bv = BitVec::new();
        bv.push_bits(0b1011, 4);
        bv.push_bit(true);
        bv.push_bits(7, 5);
        let mut r = BitReader::new(&bv);
        assert_eq!(r.read_bits(4).unwrap(), 0b1011);
        assert!(r.read_bit().unwrap());
        assert_eq!(r.read_bits(5).unwrap(), 7);
        r.finish().unwrap();
    }

    #[test]
    fn encode_lengths_match_formula() {
        let g = paper_geom();
        let serial = Operation::serial(GateOp::nor(g.col(2, 1), g.col(2, 3), g.col(7, 5)));
        for m in [ModelKind::Baseline, ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
            m.check(&serial, &g, GateSet::NotNor).unwrap();
            let bits = encode(m, &serial, &g).unwrap();
            assert_eq!(bits.len(), message_bits(m, &g), "{}", m.name());
            // decode parses without error and round-trips structurally
            let msg = decode(m, &bits, &g).unwrap();
            let again = message_to_bits(&msg, &g);
            assert_eq!(again, bits, "{} bit round-trip", m.name());
        }
    }

    #[test]
    fn unlimited_encodes_split_input() {
        let g = paper_geom();
        let op = Operation::serial(GateOp::nor(g.col(0, 4), g.col(3, 9), g.col(5, 2)));
        let bits = encode(ModelKind::Unlimited, &op, &g).unwrap();
        let Message::Unlimited { parts, selects } = decode(ModelKind::Unlimited, &bits, &g).unwrap() else {
            panic!("wrong variant")
        };
        assert_eq!(parts[0].opcode, Opcode { in_a: true, in_b: false, out: false });
        assert_eq!(parts[3].opcode, Opcode { in_a: false, in_b: true, out: false });
        assert_eq!(parts[5].opcode, Opcode::OUTPUT);
        assert_eq!(parts[0].ia, 4);
        assert_eq!(parts[3].ib, 9);
        assert_eq!(parts[5].io, 2);
        // conducting exactly inside [0, 5]
        assert_eq!(selects.iter().filter(|&&s| !s).count(), 5);
    }

    /// Regression: a split-input gate under the minimal codec used to hit an
    /// `.expect("input partition exists")` deep in `to_message` — a
    /// malformed-but-unchecked operation could panic the encoding thread.
    /// Every malformed shape must come back as a clean `Err`.
    #[test]
    fn minimal_split_input_fails_cleanly() {
        let g = paper_geom();
        // Inputs straddle partitions 0 and 3: no input partition exists.
        let split = Operation::serial(GateOp::nor(g.col(0, 4), g.col(3, 9), g.col(5, 2)));
        let err = to_message(ModelKind::Minimal, &split, &g).expect_err("split input must not encode under minimal");
        assert!(format!("{err:#}").contains("split-input"), "unexpected error: {err:#}");
        assert!(to_message(ModelKind::Standard, &split, &g).is_err());
        // Empty gate cycles are rejected for every model instead of
        // indexing out of bounds.
        for m in [ModelKind::Baseline, ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
            assert!(to_message(m, &Operation::Gates(vec![]), &g).is_err(), "{}", m.name());
        }
    }

    #[test]
    fn init_rejected_by_gate_codec() {
        let g = paper_geom();
        let op = Operation::init1(vec![0, 1]);
        assert!(encode(ModelKind::Standard, &op, &g).is_err());
    }

    #[test]
    fn wrong_length_rejected() {
        let g = paper_geom();
        let mut bv = BitVec::new();
        bv.push_bits(0, 35);
        assert!(decode(ModelKind::Minimal, &bv, &g).is_err());
    }

    /// The typed codec under NOT/NOR is the paper codec, bit for bit: the
    /// gate-type field is zero bits wide, so nothing about the published
    /// 30/607/79/36-bit formats changes.
    #[test]
    fn notnor_typed_codec_is_bit_identical() {
        let g = paper_geom();
        let op = Operation::serial(GateOp::nor(g.col(2, 1), g.col(2, 3), g.col(7, 5)));
        for m in ModelKind::ALL {
            assert_eq!(message_bits_for(m, &g, GateSet::NotNor), message_bits(m, &g));
            let plain = encode(m, &op, &g).unwrap();
            let typed = encode_with(m, &op, &g, GateSet::NotNor).unwrap();
            assert_eq!(plain, typed, "{}", m.name());
            let (class, msg) = decode_with(m, &typed, &g, GateSet::NotNor).unwrap();
            assert_eq!(class, crate::crossbar::gate::GateType::Nor);
            assert_eq!(msg, decode(m, &plain, &g).unwrap());
        }
    }

    /// The HashPIM set (NOR/OR/XOR wire classes) costs exactly 2 extra bits
    /// per message and round-trips each class, NOT riding the NOR class.
    #[test]
    fn hashpim_typed_codec_roundtrips_classes() {
        use crate::crossbar::gate::GateType;
        let g = paper_geom();
        let gs = GateSet::HashPim;
        for m in ModelKind::ALL {
            assert_eq!(message_bits_for(m, &g, gs), message_bits(m, &g) + 2, "{}", m.name());
        }
        let cases = [
            (GateOp { gate: GateType::Xor, ins: vec![g.col(2, 1), g.col(2, 3)], out: g.col(7, 5) }, GateType::Xor),
            (GateOp { gate: GateType::Or, ins: vec![g.col(2, 1), g.col(2, 3)], out: g.col(7, 5) }, GateType::Or),
            (GateOp::nor(g.col(2, 1), g.col(2, 3), g.col(7, 5)), GateType::Nor),
            (GateOp::not(g.col(2, 1), g.col(7, 5)), GateType::Nor),
        ];
        for (gate, want_class) in cases {
            let op = Operation::serial(gate);
            for m in ModelKind::ALL {
                let bits = encode_with(m, &op, &g, gs).unwrap();
                assert_eq!(bits.len(), message_bits_for(m, &g, gs));
                let (class, _) = decode_with(m, &bits, &g, gs).unwrap();
                assert_eq!(class, want_class, "{}", m.name());
            }
        }
    }

    /// The gate-type field is per-cycle: a cycle mixing wire classes has no
    /// encoding, and a class outside the set is rejected.
    #[test]
    fn mixed_or_foreign_classes_rejected() {
        use crate::crossbar::gate::GateType;
        let g = paper_geom();
        let mixed = Operation::Gates(vec![
            GateOp { gate: GateType::Xor, ins: vec![g.col(0, 1), g.col(0, 3)], out: g.col(1, 5) },
            GateOp::nor(g.col(4, 1), g.col(4, 3), g.col(5, 5)),
        ]);
        assert!(cycle_wire_class(&mixed, GateSet::HashPim).is_err());
        assert!(encode_with(ModelKind::Minimal, &mixed, &g, GateSet::HashPim).is_err());
        // XOR has no wire class under NOT/NOR.
        let xor = Operation::serial(GateOp { gate: GateType::Xor, ins: vec![g.col(0, 1), g.col(0, 3)], out: g.col(1, 5) });
        assert!(encode_with(ModelKind::Minimal, &xor, &g, GateSet::NotNor).is_err());
        // Min3 has no half-gate wire class even under FELIX.
        let min3 = Operation::serial(GateOp { gate: GateType::Min3, ins: vec![g.col(0, 1), g.col(0, 2), g.col(0, 3)], out: g.col(1, 5) });
        assert!(cycle_wire_class(&min3, GateSet::Felix).is_err());
    }
}
