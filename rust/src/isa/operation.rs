//! Abstract partition operations and their section structure.

use crate::crossbar::gate::{GateSet, GateType};
use crate::crossbar::geometry::Geometry;
use anyhow::{bail, ensure, Result};

/// A single stateful-logic gate within an operation: `out = gate(ins...)`,
/// all columns given as absolute bitline indices.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GateOp {
    pub gate: GateType,
    pub ins: Vec<usize>,
    pub out: usize,
}

impl GateOp {
    pub fn nor(a: usize, b: usize, out: usize) -> Self {
        Self { gate: GateType::Nor, ins: vec![a, b], out }
    }

    pub fn not(a: usize, out: usize) -> Self {
        Self { gate: GateType::Not, ins: vec![a], out }
    }

    /// Inclusive partition interval spanned by this gate (its *section* in a
    /// tight division).
    pub fn span(&self, geom: &Geometry) -> (usize, usize) {
        let mut lo = geom.partition_of(self.out);
        let mut hi = lo;
        for &c in &self.ins {
            let p = geom.partition_of(c);
            lo = lo.min(p);
            hi = hi.max(p);
        }
        (lo, hi)
    }

    /// Partition holding the inputs, if they all share one (`None` for
    /// split-input gates, which only the unlimited model supports).
    pub fn input_partition(&self, geom: &Geometry) -> Option<usize> {
        let mut it = self.ins.iter().map(|&c| geom.partition_of(c));
        let first = it.next()?;
        it.all(|p| p == first).then_some(first)
    }

    /// Signed partition distance `partition(out) - partition(ins)`
    /// (`None` for split-input gates).
    pub fn distance(&self, geom: &Geometry) -> Option<isize> {
        let pi = self.input_partition(geom)?;
        Some(geom.partition_of(self.out) as isize - pi as isize)
    }

    /// The gate's direction, if it crosses partitions.
    pub fn direction(&self, geom: &Geometry) -> Option<Direction> {
        match self.distance(geom) {
            Some(d) if d > 0 => Some(Direction::InputsLeft),
            Some(d) if d < 0 => Some(Direction::OutputsLeft),
            _ => None,
        }
    }
}

/// Global direction of a semi-parallel operation (Section 3.1: *Uniform
/// Direction* — "inputs left of outputs" or "outputs left of inputs").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Inputs are in partitions left of (below) their outputs.
    InputsLeft,
    /// Outputs are in partitions left of (below) their inputs.
    OutputsLeft,
}

/// Classification of an operation per Section 2.1 / Figure 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// One gate, transistors conducting across its span (Figure 2(a)).
    Serial,
    /// One gate per partition, all transistors isolating (Figure 2(b)).
    Parallel,
    /// Anything in between (Figures 2(c,d)).
    SemiParallel,
    /// Initialization write (not a stateful-logic cycle).
    Init,
}

/// One simulated cycle of the crossbar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Operation {
    /// A stateful-logic cycle: a set of gates executing concurrently in
    /// pairwise-disjoint sections.
    Gates(Vec<GateOp>),
    /// An initialization write cycle: set `cols` to `value`. Writes do not
    /// involve partition isolation and may touch any number of columns.
    Init { cols: Vec<usize>, value: bool },
}

impl Operation {
    /// Single-gate (serial) operation.
    pub fn serial(g: GateOp) -> Self {
        Operation::Gates(vec![g])
    }

    /// Initialization of `cols` to logical one (the MAGIC precondition).
    pub fn init1(cols: Vec<usize>) -> Self {
        Operation::Init { cols, value: true }
    }

    /// Number of stateful gates executed by this cycle (0 for inits).
    pub fn gate_count(&self) -> usize {
        match self {
            Operation::Gates(gs) => gs.len(),
            Operation::Init { .. } => 0,
        }
    }

    /// Validate the operation against the crossbar structure: column ranges,
    /// gate-set membership, output/input aliasing, and pairwise-disjoint
    /// sections (the physical isolation requirement).
    pub fn validate(&self, geom: &Geometry, gate_set: GateSet) -> Result<()> {
        match self {
            Operation::Init { cols, .. } => {
                ensure!(!cols.is_empty(), "empty init operation");
                for &c in cols {
                    ensure!(c < geom.n, "init column {c} out of range (n={})", geom.n);
                }
                Ok(())
            }
            Operation::Gates(gates) => {
                ensure!(!gates.is_empty(), "empty gate operation");
                let mut spans: Vec<(usize, usize)> = Vec::with_capacity(gates.len());
                for g in gates {
                    ensure!(!g.gate.is_init(), "init pseudo-gate {:?} inside a Gates cycle; use Operation::Init", g.gate);
                    gate_set.check(g.gate)?;
                    ensure!(g.ins.len() == g.gate.arity(), "gate {:?} expects {} inputs, got {}", g.gate, g.gate.arity(), g.ins.len());
                    ensure!(g.out < geom.n, "output column {} out of range (n={})", g.out, geom.n);
                    for &c in &g.ins {
                        ensure!(c < geom.n, "input column {c} out of range (n={})", geom.n);
                        ensure!(c != g.out, "gate output column {} aliases an input", g.out);
                    }
                    spans.push(g.span(geom));
                }
                spans.sort_unstable();
                for w in spans.windows(2) {
                    ensure!(w[0].1 < w[1].0, "sections {:?} and {:?} overlap: concurrent gates must occupy disjoint partition intervals", w[0], w[1]);
                }
                Ok(())
            }
        }
    }

    /// The sections of a *tight* division (Section 3.2.2): one inclusive
    /// partition interval per gate, sorted. Partitions not covered form
    /// implicit single-partition gate-less sections.
    pub fn sections(&self, geom: &Geometry) -> Vec<(usize, usize)> {
        match self {
            Operation::Init { .. } => vec![],
            Operation::Gates(gates) => {
                let mut s: Vec<(usize, usize)> = gates.iter().map(|g| g.span(geom)).collect();
                s.sort_unstable();
                s
            }
        }
    }

    /// Transistor selects of the tight division: `selects[t]` is `true` when
    /// the transistor between partitions `t` and `t+1` is **non-conducting**
    /// (isolating). Tight: conducting only strictly inside a gate's span.
    pub fn tight_selects(&self, geom: &Geometry) -> Vec<bool> {
        let sections = self.sections(geom);
        let mut selects = vec![true; geom.k.saturating_sub(1)];
        for (lo, hi) in sections {
            for t in lo..hi {
                selects[t] = false;
            }
        }
        selects
    }

    /// Classify per Section 2.1.
    pub fn kind(&self, geom: &Geometry) -> OpKind {
        match self {
            Operation::Init { .. } => OpKind::Init,
            Operation::Gates(gates) => {
                if gates.len() == 1 {
                    OpKind::Serial
                } else if gates.iter().all(|g| {
                    let (lo, hi) = g.span(geom);
                    lo == hi
                }) && gates.len() == geom.k
                {
                    OpKind::Parallel
                } else {
                    OpKind::SemiParallel
                }
            }
        }
    }

    /// The uniform direction of the operation if one exists: `Ok(None)` when
    /// no gate crosses partitions, `Err` when gates disagree.
    pub fn uniform_direction(&self, geom: &Geometry) -> Result<Option<Direction>> {
        let Operation::Gates(gates) = self else {
            return Ok(None);
        };
        let mut dir: Option<Direction> = None;
        for g in gates {
            if let Some(d) = g.direction(geom) {
                match dir {
                    None => dir = Some(d),
                    Some(prev) if prev == d => {}
                    Some(prev) => bail!("mixed directions {prev:?} and {d:?} in one operation"),
                }
            }
        }
        Ok(dir)
    }

    /// Canonical form for comparing reconstructed operations: `NOR(a, a)` is
    /// normalized to `NOT(a)`, commutative gates get their input columns
    /// sorted (input order is not observable on the wire or in the executed
    /// semantics), and gates are sorted by output column.
    pub fn normalized(&self) -> Operation {
        match self {
            Operation::Init { cols, value } => {
                let mut c = cols.clone();
                c.sort_unstable();
                c.dedup();
                Operation::Init { cols: c, value: *value }
            }
            Operation::Gates(gates) => {
                let mut gs: Vec<GateOp> = gates
                    .iter()
                    .map(|g| {
                        if g.gate == GateType::Nor && g.ins.len() == 2 && g.ins[0] == g.ins[1] {
                            GateOp::not(g.ins[0], g.out)
                        } else {
                            let mut g = g.clone();
                            if g.gate.commutative() {
                                g.ins.sort_unstable();
                            }
                            g
                        }
                    })
                    .collect();
                gs.sort_by_key(|g| g.out);
                Operation::Gates(gs)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Geometry {
        Geometry::new(256, 8, 8).unwrap() // m = 32
    }

    #[test]
    fn serial_operation_validates() {
        let g = geom();
        let op = Operation::serial(GateOp::nor(0, 1, 100));
        op.validate(&g, GateSet::NotNor).unwrap();
        assert_eq!(op.kind(&g), OpKind::Serial);
        assert_eq!(op.sections(&g), vec![(0, 3)]);
        // Tight selects: conducting only inside [0, 3].
        assert_eq!(op.tight_selects(&g), vec![false, false, false, true, true, true, true]);
    }

    #[test]
    fn parallel_operation() {
        let g = geom();
        let gates: Vec<GateOp> = (0..8).map(|p| GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 2))).collect();
        let op = Operation::Gates(gates);
        op.validate(&g, GateSet::NotNor).unwrap();
        assert_eq!(op.kind(&g), OpKind::Parallel);
        assert!(op.tight_selects(&g).iter().all(|&s| s));
    }

    #[test]
    fn semi_parallel_fig2c() {
        // Figure 2(c): two concurrent gates, each input partition p, output
        // partition p+1 — distances (1, 1).
        let g = geom();
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(1, 3)),
            GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(3, 3)),
        ]);
        op.validate(&g, GateSet::NotNor).unwrap();
        assert_eq!(op.kind(&g), OpKind::SemiParallel);
        assert_eq!(op.sections(&g), vec![(0, 1), (2, 3)]);
        assert_eq!(op.uniform_direction(&g).unwrap(), Some(Direction::InputsLeft));
    }

    #[test]
    fn overlapping_sections_rejected() {
        let g = geom();
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(2, 3)), // span [0,2]
            GateOp::nor(g.col(1, 0), g.col(1, 1), g.col(1, 3)), // span [1,1]
        ]);
        assert!(op.validate(&g, GateSet::NotNor).is_err());
    }

    #[test]
    fn mixed_direction_detected() {
        let g = geom();
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(1, 3)), // rightward
            GateOp::nor(g.col(5, 0), g.col(5, 1), g.col(4, 3)), // leftward
        ]);
        // Physically executable — the sections are disjoint — but opposing
        // directions have no representation in the shared-direction
        // standard/minimal wire formats. The verifier classifies this
        // explicitly as rule V012 (`verify::Rule::MixedDirection`): a
        // warning under the unlimited model, an error under
        // standard/minimal (see DESIGN.md §Verifier).
        op.validate(&g, GateSet::NotNor).unwrap();
        assert!(op.uniform_direction(&g).is_err());
    }

    #[test]
    fn split_input_distance_none() {
        let g = geom();
        let gate = GateOp::nor(g.col(0, 0), g.col(1, 1), g.col(2, 3));
        assert_eq!(gate.input_partition(&g), None);
        assert_eq!(gate.distance(&g), None);
    }

    #[test]
    fn normalization_folds_nor_self_to_not() {
        let op = Operation::Gates(vec![GateOp { gate: GateType::Nor, ins: vec![5, 5], out: 9 }]);
        assert_eq!(op.normalized(), Operation::Gates(vec![GateOp::not(5, 9)]));
    }

    #[test]
    fn normalization_sorts_commutative_inputs() {
        // NOR is commutative, so the two reconstructions of the same wire
        // message must compare equal regardless of input-slot order.
        let ab = Operation::Gates(vec![GateOp::nor(3, 7, 9)]);
        let ba = Operation::Gates(vec![GateOp::nor(7, 3, 9)]);
        assert_ne!(ab, ba);
        assert_eq!(ab.normalized(), ba.normalized());
        // NOT has one input: nothing to sort, nothing lost.
        let n = Operation::serial(GateOp::not(4, 6));
        assert_eq!(n.normalized(), n);
    }
}
