//! The per-partition half-gate opcode (Table 1 of the paper).
//!
//! Under the half-gates technique each partition's column decoder receives a
//! 3-bit opcode: two bits enable the input decoder units (`InA`, `InB`) and
//! one bit enables the output decoder unit (`Out`). A partition applying only
//! input voltages or only output voltages executes *half* a gate; the
//! combination of half-gates within one section forms a valid gate.

use std::fmt;

/// Table 1: the opcode of an individual partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Opcode {
    /// Apply `V_IN` at the partition's `InA` index.
    pub in_a: bool,
    /// Apply `V_IN` at the partition's `InB` index.
    pub in_b: bool,
    /// Apply `V_OUT` at the partition's `Out` index.
    pub out: bool,
}

impl Opcode {
    /// `000` — apply no voltages (unused / intermediate partition).
    pub const IDLE: Opcode = Opcode { in_a: false, in_b: false, out: false };
    /// `111` — full gate within this partition.
    pub const FULL: Opcode = Opcode { in_a: true, in_b: true, out: true };
    /// `110` — `Gate(InA, InB) → ?`: the input half of a half-gate pair.
    pub const INPUTS: Opcode = Opcode { in_a: true, in_b: true, out: false };
    /// `001` — `? → Out`: the output half of a half-gate pair.
    pub const OUTPUT: Opcode = Opcode { in_a: false, in_b: false, out: true };

    /// Table 1 index: `InA·4 + InB·2 + Out`.
    #[inline]
    pub fn index(&self) -> u8 {
        (self.in_a as u8) << 2 | (self.in_b as u8) << 1 | self.out as u8
    }

    /// Inverse of [`Opcode::index`].
    #[inline]
    pub fn from_index(i: u8) -> Opcode {
        Opcode { in_a: i & 4 != 0, in_b: i & 2 != 0, out: i & 1 != 0 }
    }

    /// Whether this partition applies any voltage at all.
    #[inline]
    pub fn is_active(&self) -> bool {
        self.in_a || self.in_b || self.out
    }
}

impl fmt::Display for Opcode {
    /// Renders the Table 1 description, e.g. `Gate(InA,?) -> Out` for 101.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if !self.is_active() {
            return write!(f, "-");
        }
        if self.in_a || self.in_b {
            let a = if self.in_a { "InA" } else { "?" };
            let b = if self.in_b { "InB" } else { "?" };
            let o = if self.out { "Out" } else { "?" };
            write!(f, "Gate({a},{b}) -> {o}")
        } else {
            write!(f, "? -> Out")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip_all_eight() {
        for i in 0..8u8 {
            assert_eq!(Opcode::from_index(i).index(), i);
        }
    }

    /// Reproduces Table 1 verbatim (experiment E1).
    #[test]
    fn table1_descriptions() {
        let expect = [
            (0b000, "-"),
            (0b001, "? -> Out"),
            (0b010, "Gate(?,InB) -> ?"),
            (0b011, "Gate(?,InB) -> Out"),
            (0b100, "Gate(InA,?) -> ?"),
            (0b101, "Gate(InA,?) -> Out"),
            (0b110, "Gate(InA,InB) -> ?"),
            (0b111, "Gate(InA,InB) -> Out"),
        ];
        for (idx, s) in expect {
            assert_eq!(Opcode::from_index(idx).to_string(), s, "opcode {idx:03b}");
        }
    }

    #[test]
    fn named_constants() {
        assert_eq!(Opcode::IDLE.index(), 0b000);
        assert_eq!(Opcode::OUTPUT.index(), 0b001);
        assert_eq!(Opcode::INPUTS.index(), 0b110);
        assert_eq!(Opcode::FULL.index(), 0b111);
    }
}
