//! The legalizer: rewriting operations a model cannot express into
//! sequences of supported alternatives (Section 5: "operations that are not
//! supported ... are replaced with alternatives that are compatible, yet
//! require additional latency").
//!
//! Strategies, mirroring the paper's footnotes 3–5:
//!
//! * **Baseline** — serialize: one gate per cycle.
//! * **Standard** — split concurrent gates into groups with identical
//!   intra-partition indices and uniform direction; split-input gates first
//!   copy `InB` into the partition of `InA` through reserved scratch columns
//!   (footnote 3: "serial algorithms may overcome this limitation by copying
//!   one of the inputs").
//! * **Minimal** — additionally group by partition distance and split each
//!   group into maximal arithmetic progressions of input partitions
//!   (the *Periodic* criterion).

use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::isa::models::ModelKind;
use crate::isa::operation::{GateOp, Operation};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Legalization context.
#[derive(Debug, Clone, Copy)]
pub struct LegalizeConfig {
    /// Two intra-partition column indices reserved (in every partition) as
    /// scratch for split-input copies. `None` forbids split-input rewrites.
    pub scratch_intra: Option<(usize, usize)>,
}

impl Default for LegalizeConfig {
    fn default() -> Self {
        Self { scratch_intra: None }
    }
}

/// Statistics of one legalization run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LegalizeStats {
    pub ops_in: usize,
    pub ops_out: usize,
    /// Operations that were already legal.
    pub passthrough: usize,
    /// Split-input copies inserted.
    pub copies_inserted: usize,
}

/// Legalize a single operation for `model`, emitting an equivalent sequence
/// of supported operations.
pub fn legalize_op(
    op: &Operation,
    model: ModelKind,
    geom: &Geometry,
    gate_set: GateSet,
    cfg: &LegalizeConfig,
    stats: &mut LegalizeStats,
) -> Result<Vec<Operation>> {
    stats.ops_in += 1;
    op.validate(geom, gate_set)?;
    if model.supports(op, geom, gate_set) {
        stats.passthrough += 1;
        stats.ops_out += 1;
        return Ok(vec![op.clone()]);
    }
    let Operation::Gates(gates) = op else {
        bail!("init operations are legal in every model and should not reach the rewrite path")
    };

    let mut out: Vec<Operation> = Vec::new();

    // Baseline: fully serialize.
    if model == ModelKind::Baseline {
        for g in gates {
            out.push(Operation::serial(g.clone()));
        }
        stats.ops_out += out.len();
        return Ok(out);
    }

    // Step 1 (standard & minimal): eliminate split-input gates by copying
    // InB into InA's partition via reserved scratch columns.
    let mut fixed: Vec<GateOp> = Vec::with_capacity(gates.len());
    for g in gates {
        if model == ModelKind::Unlimited || g.input_partition(geom).is_some() {
            fixed.push(g.clone());
            continue;
        }
        let Some((s1, s2)) = cfg.scratch_intra else {
            bail!("split-input gate under {} requires scratch columns (LegalizeConfig::scratch_intra)", model.name());
        };
        let pa = geom.partition_of(g.ins[0]);
        let b = g.ins[1];
        let c1 = geom.col(pa, s1);
        let c2 = geom.col(pa, s2);
        // init scratch; t = NOT(b); b' = NOT(t) — lands b in partition pa.
        out.push(Operation::init1(vec![c1, c2]));
        out.push(Operation::serial(GateOp::not(b, c1)));
        out.push(Operation::serial(GateOp::not(c1, c2)));
        stats.copies_inserted += 1;
        fixed.push(GateOp { gate: g.gate, ins: vec![g.ins[0], c2], out: g.out });
    }

    if model == ModelKind::Unlimited {
        // Physically-valid unlimited ops are always supported; reaching here
        // means the op itself was invalid and validate() already failed.
        out.push(Operation::Gates(fixed));
        stats.ops_out += out.len();
        return Ok(out);
    }

    // Step 2: group by identical intra-partition indices and direction sign.
    // Key: (ia, ib, io, signum(distance)).
    let mut groups: BTreeMap<(usize, usize, usize, i8), Vec<GateOp>> = BTreeMap::new();
    for g in fixed {
        let ia = geom.intra(g.ins[0]);
        let ib = geom.intra(*g.ins.get(1).unwrap_or(&g.ins[0]));
        let io = geom.intra(g.out);
        let sign = g.distance(geom).expect("split inputs eliminated above").signum() as i8;
        groups.entry((ia, ib, io, sign)).or_default().push(g);
    }

    for ((_, _, _, _), group) in groups {
        if model == ModelKind::Standard {
            out.push(Operation::Gates(group));
            continue;
        }
        // Minimal: group by |distance|, then split into periodic runs.
        let mut by_dist: BTreeMap<usize, Vec<GateOp>> = BTreeMap::new();
        for g in group {
            by_dist.entry(g.distance(geom).unwrap().unsigned_abs()).or_default().push(g);
        }
        for (d, mut gs) in by_dist {
            gs.sort_by_key(|g| g.input_partition(geom).unwrap());
            let inputs: Vec<usize> = gs.iter().map(|g| g.input_partition(geom).unwrap()).collect();
            for run in split_periodic(&inputs, d) {
                let op_gates: Vec<GateOp> = run.iter().map(|&idx| gs[idx].clone()).collect();
                out.push(Operation::Gates(op_gates));
            }
        }
    }

    // Every emitted operation must now be legal.
    for o in &out {
        model.check(o, geom, gate_set)?;
    }
    stats.ops_out += out.len();
    Ok(out)
}

/// Split sorted input-partition positions into maximal runs forming
/// arithmetic progressions with common difference `> d` (the *Periodic*
/// criterion: `T` greater than the partition distance). Returns index runs
/// into the input slice.
pub fn split_periodic(inputs: &[usize], d: usize) -> Vec<Vec<usize>> {
    let mut runs: Vec<Vec<usize>> = Vec::new();
    let mut i = 0usize;
    while i < inputs.len() {
        let mut run = vec![i];
        if i + 1 < inputs.len() {
            let gap = inputs[i + 1] - inputs[i];
            if gap > d {
                let mut j = i + 1;
                while j < inputs.len() && inputs[j] - inputs[j - 1] == gap {
                    run.push(j);
                    j += 1;
                }
            }
        }
        i += run.len();
        runs.push(run);
    }
    runs
}

/// Legalize a whole program (sequence of operations).
pub fn legalize_program(
    ops: &[Operation],
    model: ModelKind,
    geom: &Geometry,
    gate_set: GateSet,
    cfg: &LegalizeConfig,
) -> Result<(Vec<Operation>, LegalizeStats)> {
    let mut stats = LegalizeStats::default();
    let mut out = Vec::with_capacity(ops.len());
    for op in ops {
        out.extend(legalize_op(op, model, geom, gate_set, cfg, &mut stats)?);
    }
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::PimBackend;
    use crate::crossbar::crossbar::Crossbar;

    fn geom() -> Geometry {
        Geometry::new(256, 8, 16).unwrap()
    }

    #[test]
    fn periodic_split_runs() {
        assert_eq!(split_periodic(&[0, 2, 4, 6], 1), vec![vec![0, 1, 2, 3]]);
        assert_eq!(split_periodic(&[0, 1, 4], 0), vec![vec![0, 1], vec![2]]);
        // gap 1 not > d=1: singletons
        assert_eq!(split_periodic(&[0, 1, 2], 1), vec![vec![0], vec![1], vec![2]]);
        assert_eq!(split_periodic(&[3], 2), vec![vec![0]]);
        // gap change splits the run
        assert_eq!(split_periodic(&[0, 2, 4, 5], 0), vec![vec![0, 1, 2], vec![3]]);
    }

    #[test]
    fn legal_op_passes_through() {
        let g = geom();
        let op = Operation::Gates((0..8).map(|p| GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 3))).collect());
        let mut stats = LegalizeStats::default();
        let out = legalize_op(&op, ModelKind::Minimal, &g, GateSet::NotNor, &LegalizeConfig::default(), &mut stats).unwrap();
        assert_eq!(out, vec![op]);
        assert_eq!(stats.passthrough, 1);
    }

    #[test]
    fn fig2d_split_for_minimal() {
        let g = geom();
        // distances (0, 1, 0) — minimal must split into d=0 and d=1 ops.
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
            GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(3, 3)),
            GateOp::nor(g.col(5, 0), g.col(5, 1), g.col(5, 3)),
        ]);
        let mut stats = LegalizeStats::default();
        let out = legalize_op(&op, ModelKind::Minimal, &g, GateSet::NotNor, &LegalizeConfig::default(), &mut stats).unwrap();
        assert_eq!(out.len(), 2, "{out:?}"); // d=0 pair {p0, p5}... wait gap 5 uniform — single run; plus d=1 op
        for o in &out {
            assert!(ModelKind::Minimal.supports(o, &g, GateSet::NotNor));
        }
    }

    #[test]
    fn intra_index_groups_for_standard() {
        let g = geom();
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
            GateOp::nor(g.col(2, 0), g.col(2, 2), g.col(2, 3)), // ib differs
        ]);
        let mut stats = LegalizeStats::default();
        let out = legalize_op(&op, ModelKind::Standard, &g, GateSet::NotNor, &LegalizeConfig::default(), &mut stats).unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn split_input_copy_preserves_semantics() {
        let g = geom();
        let gate_set = GateSet::NotNor;
        // NOR with inputs in different partitions.
        let op = Operation::serial(GateOp::nor(g.col(0, 0), g.col(3, 7), g.col(5, 9)));
        let cfg = LegalizeConfig { scratch_intra: Some((30, 31)) };
        let mut stats = LegalizeStats::default();
        let out = legalize_op(&op, ModelKind::Standard, &g, gate_set, &cfg, &mut stats).unwrap();
        assert_eq!(stats.copies_inserted, 1);
        assert!(out.len() > 1);

        // Execute both paths and compare the gate's output column.
        let mut direct = Crossbar::new(g, gate_set);
        direct.state.fill_random(5);
        let mut legal = direct.clone();
        direct.execute(&op).unwrap();
        legal.execute_ops(&out).unwrap();
        for r in 0..g.rows {
            assert_eq!(direct.state.get(r, g.col(5, 9)), legal.state.get(r, g.col(5, 9)), "row {r}");
        }
    }

    #[test]
    fn split_input_without_scratch_fails() {
        let g = geom();
        let op = Operation::serial(GateOp::nor(g.col(0, 0), g.col(3, 7), g.col(5, 9)));
        let mut stats = LegalizeStats::default();
        assert!(legalize_op(&op, ModelKind::Standard, &g, GateSet::NotNor, &LegalizeConfig::default(), &mut stats).is_err());
    }

    #[test]
    fn baseline_serializes() {
        let g = geom();
        let op = Operation::Gates((0..8).map(|p| GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 3))).collect());
        let mut stats = LegalizeStats::default();
        let out = legalize_op(&op, ModelKind::Baseline, &g, GateSet::NotNor, &LegalizeConfig::default(), &mut stats).unwrap();
        assert_eq!(out.len(), 8);
    }
}
