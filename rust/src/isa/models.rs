//! The three partition designs as operation validators.
//!
//! * **Unlimited** (Section 2): any set of concurrent gates in disjoint
//!   sections — including split-input gates and per-partition indices.
//! * **Standard** (Section 3): adds *Identical Indices*, *No Split-Input*
//!   and *Uniform Direction*.
//! * **Minimal** (Section 4): adds *Uniform Partition-Distance* and
//!   *Periodic* (gates repeat every `T` partitions, `T` greater than the
//!   partition distance).
//! * **Baseline**: a crossbar without partitions — serial gates only.

use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::isa::operation::Operation;
use anyhow::{ensure, Result};

/// Which design a controller / crossbar pair implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// No partitions: one gate per cycle, 3·log2(n)-bit messages.
    Baseline,
    /// Section 2: full generality, 3k·log2(n/k) + 3k + (k-1)-bit messages.
    Unlimited,
    /// Section 3: shared intra-partition indices + generated opcodes,
    /// 3·log2(n/k) + (2k-1) + 1-bit messages.
    Standard,
    /// Section 4: periodic inter-partition patterns + range generator,
    /// 3·log2(n/k) + 4·log2(k) + 1-bit messages.
    Minimal,
}

impl ModelKind {
    pub const ALL: [ModelKind; 4] = [ModelKind::Baseline, ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal];

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Baseline => "baseline",
            ModelKind::Unlimited => "unlimited",
            ModelKind::Standard => "standard",
            ModelKind::Minimal => "minimal",
        }
    }

    /// Validate `op` against this model's operation set. Initialization
    /// writes are legal in every model (they are write commands, outside the
    /// paper's gate-operation formats — see DESIGN.md).
    pub fn check(&self, op: &Operation, geom: &Geometry, gate_set: GateSet) -> Result<()> {
        op.validate(geom, gate_set)?;
        if matches!(op, Operation::Init { .. }) {
            return Ok(());
        }
        match self {
            ModelKind::Baseline => check_baseline(op, geom),
            ModelKind::Unlimited => Ok(()),
            ModelKind::Standard => check_standard(op, geom),
            ModelKind::Minimal => {
                check_standard(op, geom)?;
                check_minimal(op, geom)
            }
        }
    }

    /// Whether `op` is legal under this model.
    pub fn supports(&self, op: &Operation, geom: &Geometry, gate_set: GateSet) -> bool {
        self.check(op, geom, gate_set).is_ok()
    }
}

fn check_baseline(op: &Operation, _geom: &Geometry) -> Result<()> {
    let Operation::Gates(gates) = op else { return Ok(()) };
    ensure!(gates.len() == 1, "baseline crossbar executes a single gate per cycle, got {}", gates.len());
    Ok(())
}

/// Section 3.1 criteria.
fn check_standard(op: &Operation, geom: &Geometry) -> Result<()> {
    let Operation::Gates(gates) = op else { return Ok(()) };

    // No Split-Input: inputs of each gate share a partition.
    for g in gates {
        ensure!(g.input_partition(geom).is_some(), "split-input gate (inputs span partitions) requires the unlimited model");
    }

    // Identical Indices: intra-partition (ia, ib, io) identical across gates.
    // A NOT gate occupies both input slots (InB := InA).
    let tuple = |g: &crate::isa::operation::GateOp| {
        let ia = geom.intra(g.ins[0]);
        let ib = geom.intra(*g.ins.get(1).unwrap_or(&g.ins[0]));
        (ia, ib, geom.intra(g.out))
    };
    let first = tuple(&gates[0]);
    for g in &gates[1..] {
        let t = tuple(g);
        ensure!(t == first, "identical-indices violation: intra indices {t:?} differ from {first:?}");
    }

    // Uniform Direction.
    op.uniform_direction(geom)?;
    Ok(())
}

/// Section 4.1 criteria (on top of standard).
fn check_minimal(op: &Operation, geom: &Geometry) -> Result<()> {
    let Operation::Gates(gates) = op else { return Ok(()) };

    // Uniform Partition-Distance: |distance| identical for all gates
    // (signs are already uniform by the standard Uniform Direction check).
    let dist = |g: &crate::isa::operation::GateOp| g.distance(geom).expect("split-input rejected by standard check").unsigned_abs();
    let d = dist(&gates[0]);
    for g in &gates[1..] {
        let di = dist(g);
        ensure!(di == d, "uniform-distance violation: distances {di} and {d} mixed in one operation");
    }

    // Periodic: input partitions form a contiguous arithmetic progression
    // with period T > d (so consecutive sections do not overlap).
    let mut inputs: Vec<usize> = gates.iter().map(|g| g.input_partition(geom).unwrap()).collect();
    inputs.sort_unstable();
    for w in inputs.windows(2) {
        ensure!(w[0] != w[1], "two gates share input partition {}", w[0]);
    }
    if inputs.len() >= 2 {
        let t = inputs[1] - inputs[0];
        ensure!(t > d, "period T={t} must exceed the partition distance d={d}");
        for w in inputs.windows(2) {
            let ti = w[1] - w[0];
            ensure!(ti == t, "aperiodic gate placement: gaps {ti} and {t} differ");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::operation::GateOp;

    fn geom() -> Geometry {
        Geometry::new(256, 8, 8).unwrap() // m = 32, k = 8
    }

    /// Figure 2(a): a serial gate — legal everywhere.
    #[test]
    fn fig2a_serial_supported_by_all() {
        let g = geom();
        let op = Operation::serial(GateOp::nor(g.col(1, 0), g.col(1, 1), g.col(4, 3)));
        for m in ModelKind::ALL {
            assert!(m.supports(&op, &g, GateSet::NotNor), "{}", m.name());
        }
    }

    /// Figure 2(b): fully parallel — legal in all partition models.
    #[test]
    fn fig2b_parallel() {
        let g = geom();
        let op = Operation::Gates((0..8).map(|p| GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 3))).collect());
        assert!(!ModelKind::Baseline.supports(&op, &g, GateSet::NotNor));
        assert!(ModelKind::Unlimited.supports(&op, &g, GateSet::NotNor));
        assert!(ModelKind::Standard.supports(&op, &g, GateSet::NotNor));
        assert!(ModelKind::Minimal.supports(&op, &g, GateSet::NotNor));
    }

    /// Figure 2(c): distances (1,1), periodic — legal in standard & minimal.
    #[test]
    fn fig2c_semi_parallel() {
        let g = geom();
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(1, 3)),
            GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(3, 3)),
            GateOp::nor(g.col(4, 0), g.col(4, 1), g.col(5, 3)),
            GateOp::nor(g.col(6, 0), g.col(6, 1), g.col(7, 3)),
        ]);
        assert!(ModelKind::Standard.supports(&op, &g, GateSet::NotNor));
        assert!(ModelKind::Minimal.supports(&op, &g, GateSet::NotNor));
    }

    /// Figure 2(d): distances (0,1,0) — standard yes, minimal no
    /// ("Figure 2(d) is rarely used — e.g., not at all in MultPIM").
    #[test]
    fn fig2d_mixed_distance_not_minimal() {
        let g = geom();
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)), // d=0
            GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(3, 3)), // d=1
            GateOp::nor(g.col(5, 0), g.col(5, 1), g.col(5, 3)), // d=0
        ]);
        assert!(ModelKind::Unlimited.supports(&op, &g, GateSet::NotNor));
        assert!(ModelKind::Standard.supports(&op, &g, GateSet::NotNor));
        assert!(!ModelKind::Minimal.supports(&op, &g, GateSet::NotNor));
    }

    #[test]
    fn identical_indices_enforced() {
        let g = geom();
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
            GateOp::nor(g.col(2, 0), g.col(2, 2), g.col(2, 3)), // ib differs
        ]);
        assert!(ModelKind::Unlimited.supports(&op, &g, GateSet::NotNor));
        assert!(!ModelKind::Standard.supports(&op, &g, GateSet::NotNor));
    }

    #[test]
    fn split_input_only_unlimited() {
        let g = geom();
        let op = Operation::serial(GateOp::nor(g.col(0, 0), g.col(1, 1), g.col(2, 3)));
        assert!(ModelKind::Unlimited.supports(&op, &g, GateSet::NotNor));
        assert!(!ModelKind::Standard.supports(&op, &g, GateSet::NotNor));
        assert!(!ModelKind::Minimal.supports(&op, &g, GateSet::NotNor));
    }

    #[test]
    fn aperiodic_rejected_by_minimal() {
        let g = geom();
        // Inputs at partitions 0, 1, 4 (gaps 1 and 3): aperiodic.
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
            GateOp::nor(g.col(1, 0), g.col(1, 1), g.col(1, 3)),
            GateOp::nor(g.col(4, 0), g.col(4, 1), g.col(4, 3)),
        ]);
        assert!(ModelKind::Standard.supports(&op, &g, GateSet::NotNor));
        assert!(!ModelKind::Minimal.supports(&op, &g, GateSet::NotNor));
    }

    #[test]
    fn period_must_exceed_distance() {
        let g = geom();
        // d=1 with T=1 would overlap sections; construction is physically
        // invalid so even Unlimited rejects (sections overlap).
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(1, 3)),
            GateOp::nor(g.col(1, 0), g.col(1, 1), g.col(2, 3)),
        ]);
        assert!(!ModelKind::Unlimited.supports(&op, &g, GateSet::NotNor));
        // d=1 with T=2 is fine.
        let op2 = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(1, 3)),
            GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(3, 3)),
        ]);
        assert!(ModelKind::Minimal.supports(&op2, &g, GateSet::NotNor));
    }

    #[test]
    fn inits_legal_everywhere() {
        let g = geom();
        let op = Operation::init1(vec![0, 5, 100, 255]);
        for m in ModelKind::ALL {
            assert!(m.supports(&op, &g, GateSet::NotNor), "{}", m.name());
        }
    }
}
