//! The partition instruction-set architecture.
//!
//! * [`operation`] — abstract operations: one stateful-logic cycle executing
//!   a set of concurrent gates in disjoint *sections* (serial / parallel /
//!   semi-parallel, Section 2.1 of the paper), or an initialization write.
//! * [`models`] — the three designs: **unlimited** (Section 2), **standard**
//!   (Section 3: identical intra-partition indices, no split-input, uniform
//!   direction) and **minimal** (Section 4: uniform partition distance,
//!   periodic), as operation validators.
//! * [`opcode`] — the per-partition half-gate opcode of Table 1.
//! * [`encode`] — bit-exact control-message codecs for every model
//!   (30 / 607 / 79 / 36 bits at n=1024, k=32, NOT/NOR gate set).
//! * [`lower`] — the legalizer: rewrites operations that a model does not
//!   support into sequences of supported alternatives (Section 5).

pub mod encode;
pub mod lower;
pub mod models;
pub mod opcode;
pub mod operation;
pub mod schedule;

pub use encode::{decode, encode, message_bits, BitVec};
pub use models::ModelKind;
pub use operation::{Direction, GateOp, OpKind, Operation};
