//! Peripheral and physical area models for every design (Sections 2.2, 3.2,
//! 4.2, 5.3.1) — experiment E12.

use crate::crossbar::geometry::Geometry;
use crate::isa::models::ModelKind;
use crate::periphery::{decoder::ColumnDecoder, opcode_gen, range_gen};

/// Aggregate periphery cost of one design on one crossbar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeripheryArea {
    /// Two-input-gate equivalents of all CMOS select logic.
    pub cmos_gates: usize,
    /// Analog multiplexers (identical crossbar interface in all designs).
    pub analog_muxes: usize,
    /// Extra control logic (opcode generator / pattern generators).
    pub extra_logic_gates: usize,
}

impl PeripheryArea {
    pub fn total_gates(&self) -> usize {
        self.cmos_gates + self.extra_logic_gates
    }
}

/// Periphery cost of the proposed design for `model` (and of the baseline).
pub fn periphery_area(model: ModelKind, geom: &Geometry) -> PeripheryArea {
    let (n, k, m) = (geom.n, geom.k, geom.m());
    match model {
        // One column decoder across all n bitlines (Figure 3(a)).
        ModelKind::Baseline => {
            let d = ColumnDecoder::for_bitlines(n);
            PeripheryArea { cmos_gates: d.cmos_gates(), analog_muxes: d.analog_muxes(), extra_logic_gates: 0 }
        }
        // Half-gates: one n/k column decoder per partition (Figure 3(c)).
        ModelKind::Unlimited => {
            let d = ColumnDecoder::for_bitlines(m);
            PeripheryArea {
                cmos_gates: k * d.cmos_gates(),
                analog_muxes: k * d.analog_muxes(),
                // 3 opcode enable gates per partition.
                extra_logic_gates: 3 * k,
            }
        }
        // Shared indices → the CMOS decoders are shared across partitions;
        // only the analog muxes replicate (Section 3.2.1), plus the opcode
        // generator (Section 3.2.2).
        ModelKind::Standard => {
            let d = ColumnDecoder::for_bitlines(m);
            PeripheryArea {
                cmos_gates: d.cmos_gates(), // shared!
                analog_muxes: k * d.analog_muxes(),
                extra_logic_gates: opcode_gen::gate_cost(k),
            }
        }
        // Standard periphery with the opcode generator replaced by the
        // range/distance pattern generators (Section 4.2).
        ModelKind::Minimal => {
            let d = ColumnDecoder::for_bitlines(m);
            PeripheryArea {
                cmos_gates: d.cmos_gates(),
                analog_muxes: k * d.analog_muxes(),
                extra_logic_gates: range_gen::gate_cost(k),
            }
        }
    }
}

/// The naive unlimited-model periphery of Figure 3(b): a stacked column
/// decoder for every possible section (every partition interval) — Ω(k²)
/// decoders. Shown only to quantify what half-gates save.
pub fn naive_unlimited_area(geom: &Geometry) -> PeripheryArea {
    let (k, m) = (geom.k, geom.m());
    let mut cmos = 0usize;
    let mut muxes = 0usize;
    for lo in 0..k {
        for hi in lo..k {
            let width = (hi - lo + 1) * m;
            let d = ColumnDecoder::for_bitlines(width.next_power_of_two());
            cmos += d.cmos_gates();
            muxes += d.analog_muxes();
        }
    }
    PeripheryArea { cmos_gates: cmos, analog_muxes: muxes, extra_logic_gates: 0 }
}

/// Physical in-array overhead of the k−1 isolation transistors per row,
/// relative to the n memristor cells of the row: `(k-1)/n` — the ≈3% the
/// paper cites for k=32, n=1024 [8].
pub fn transistor_area_overhead(geom: &Geometry) -> f64 {
    (geom.k as f64 - 1.0) / geom.n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper() -> Geometry {
        Geometry::paper(64).unwrap()
    }

    /// Section 2.2 / 5.3.1: the proposed periphery needs slightly *fewer*
    /// CMOS gates than a partition-free crossbar.
    #[test]
    fn halfgate_periphery_cheaper_than_baseline() {
        let g = paper();
        let base = periphery_area(ModelKind::Baseline, &g);
        for m in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
            let a = periphery_area(m, &g);
            assert!(a.cmos_gates < base.cmos_gates, "{}: {} !< {}", m.name(), a.cmos_gates, base.cmos_gates);
            // Analog mux totals unchanged (the crossbar interface is identical).
            assert_eq!(a.analog_muxes, base.analog_muxes);
        }
    }

    /// Figure 3(b): the naive decoder stack is catastrophically larger.
    #[test]
    fn naive_stack_is_omega_k_squared() {
        let g = paper();
        let naive = naive_unlimited_area(&g);
        let ours = periphery_area(ModelKind::Unlimited, &g);
        assert!(naive.cmos_gates > 50 * ours.cmos_gates, "naive {} vs half-gates {}", naive.cmos_gates, ours.cmos_gates);
        // The stack replicates analog muxes too; half-gates keeps them flat.
        assert!(naive.analog_muxes > 100 * g.n);
    }

    /// Preliminary estimate the paper quotes from [8]: ≈3% transistor area
    /// overhead at k=32.
    #[test]
    fn transistor_overhead_three_percent() {
        let oh = transistor_area_overhead(&paper());
        assert!((oh - 0.0303).abs() < 0.001, "got {oh}");
    }

    /// Standard/minimal extra logic stays negligible vs decoder gates.
    #[test]
    fn pattern_logic_negligible() {
        let g = paper();
        for m in [ModelKind::Standard, ModelKind::Minimal] {
            let a = periphery_area(m, &g);
            assert!(a.extra_logic_gates < periphery_area(ModelKind::Baseline, &g).cmos_gates / 10);
        }
    }
}
