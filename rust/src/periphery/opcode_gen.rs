//! The standard model's opcode generator (Section 3.2.2, Figure 5).
//!
//! In a *tight* section division the first and last partitions of every
//! gate-containing section apply voltages, and intermediate partitions are
//! idle. The opcode of each partition is therefore derivable from (a) the
//! transistor selects, (b) a per-partition enable bit, and (c) the global
//! direction bit — realized in hardware by two 2:1 multiplexers per
//! partition, O(k) gates total.

use crate::isa::opcode::Opcode;
use crate::isa::operation::Direction;
use anyhow::{ensure, Result};

/// Derive the per-partition opcodes. For direction *inputs left of outputs*:
/// the input bits of partition `p` are one when the transistor to its left
/// is selected (or `p` is the crossbar edge), the output bit when the
/// transistor to its right is selected — and vice versa for *outputs left of
/// inputs*; everything ANDed with the partition's enable.
pub fn generate(enables: &[bool], selects: &[bool], dir: Direction) -> Result<Vec<Opcode>> {
    let k = enables.len();
    ensure!(selects.len() + 1 == k, "expected {} selects for {k} partitions, got {}", k - 1, selects.len());
    let mut opcodes = Vec::with_capacity(k);
    for p in 0..k {
        let left_boundary = p == 0 || selects[p - 1];
        let right_boundary = p == k - 1 || selects[p];
        let (in_bit, out_bit) = match dir {
            Direction::InputsLeft => (left_boundary, right_boundary),
            Direction::OutputsLeft => (right_boundary, left_boundary),
        };
        opcodes.push(Opcode {
            in_a: in_bit && enables[p],
            in_b: in_bit && enables[p],
            out: out_bit && enables[p],
        });
    }
    Ok(opcodes)
}

/// Hardware cost of the opcode generator: two 2:1 multiplexers per partition
/// (each ≈ 3 two-input gate equivalents) — negligible next to the
/// `O(n log k)` decoder gates, as the paper notes.
pub fn gate_cost(k: usize) -> usize {
    2 * k * 3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_partition_section_gets_full_opcode() {
        // k = 4, all transistors selected, p1 enabled: in-place gate in p1.
        let opcodes = generate(&[false, true, false, false], &[true, true, true], Direction::InputsLeft).unwrap();
        assert_eq!(opcodes[1], Opcode::FULL);
        assert_eq!(opcodes[0], Opcode::IDLE);
        assert_eq!(opcodes[2], Opcode::IDLE);
    }

    #[test]
    fn two_partition_section_splits_into_half_gates() {
        // k = 4, section [1, 2] (selects: t0 = isolate, t1 = conduct,
        // t2 = isolate), p1 and p2 enabled, inputs left.
        let opcodes = generate(&[false, true, true, false], &[true, false, true], Direction::InputsLeft).unwrap();
        assert_eq!(opcodes[1], Opcode::INPUTS); // 110
        assert_eq!(opcodes[2], Opcode::OUTPUT); // 001
    }

    #[test]
    fn direction_flips_half_gate_roles() {
        let opcodes = generate(&[false, true, true, false], &[true, false, true], Direction::OutputsLeft).unwrap();
        assert_eq!(opcodes[1], Opcode::OUTPUT);
        assert_eq!(opcodes[2], Opcode::INPUTS);
    }

    #[test]
    fn intermediate_partitions_idle() {
        // k = 4, single section [0, 3], only edges enabled.
        let opcodes = generate(&[true, false, false, true], &[false, false, false], Direction::InputsLeft).unwrap();
        assert_eq!(opcodes[0], Opcode::INPUTS);
        assert_eq!(opcodes[1], Opcode::IDLE);
        assert_eq!(opcodes[2], Opcode::IDLE);
        assert_eq!(opcodes[3], Opcode::OUTPUT);
    }

    #[test]
    fn disabled_partitions_never_drive() {
        let opcodes = generate(&[false; 4], &[true, true, true], Direction::InputsLeft).unwrap();
        assert!(opcodes.iter().all(|o| !o.is_active()));
    }

    #[test]
    fn cost_is_linear_in_k() {
        assert_eq!(gate_cost(32), 192);
        assert!(gate_cost(32) < 1024); // negligible vs O(n log k)
    }
}
