//! The minimal model's pattern generators (Section 4.2).
//!
//! * **Range generator** — input opcodes: logical one every period `T`, from
//!   `p_start` to `p_end` (two shifters + a period decoder on width `k`).
//! * **Distance shifter** — output opcodes: the input opcode vector shifted
//!   by the partition distance in the global direction (up to `k` in either
//!   direction).
//! * **Select derivation** — a separation transistor is non-conducting when
//!   its left neighbour partition emits output voltages or its right
//!   neighbour emits input voltages (for direction *inputs left of outputs*;
//!   mirrored otherwise).

use crate::isa::operation::Direction;
use anyhow::{ensure, Result};

/// The wire-level parameters of a minimal-model gate message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RangeParams {
    /// First input partition.
    pub p_start: usize,
    /// Last input partition (inclusive).
    pub p_end: usize,
    /// Period in partitions (`T ≥ 1`; `T > distance` when more than one gate
    /// fires).
    pub t: usize,
    /// Partition distance between each gate's inputs and output.
    pub distance: usize,
    /// Global direction.
    pub dir: Direction,
}

/// The pattern-generator outputs: which partitions drive input voltages,
/// which drive output voltages, and the derived transistor selects
/// (`true` = non-conducting).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Expansion {
    pub in_mask: Vec<bool>,
    pub out_mask: Vec<bool>,
    pub selects: Vec<bool>,
}

/// Expand range parameters into per-partition masks — the functional model
/// of the minimal periphery.
pub fn expand(params: &RangeParams, k: usize) -> Result<Expansion> {
    let RangeParams { p_start, p_end, t, distance, dir } = *params;
    ensure!(t >= 1, "period T must be at least 1");
    ensure!(p_start < k && p_end < k, "range [{p_start}, {p_end}] exceeds k={k}");
    ensure!(p_start <= p_end, "p_start {p_start} > p_end {p_end}");
    ensure!(distance < k, "distance {distance} exceeds k={k}");
    if p_end > p_start {
        ensure!(t > distance, "period T={t} must exceed distance d={distance} (sections would overlap)");
    }

    // Range generator: ones every T from p_start to p_end.
    let mut in_mask = vec![false; k];
    let mut p = p_start;
    while p <= p_end {
        in_mask[p] = true;
        p += t;
    }

    // Distance shifter: outputs at inputs ± distance.
    let mut out_mask = vec![false; k];
    for p in 0..k {
        if in_mask[p] {
            let q = match dir {
                Direction::InputsLeft => p.checked_add(distance).filter(|&q| q < k),
                Direction::OutputsLeft => p.checked_sub(distance),
            };
            let q = q.ok_or_else(|| anyhow::anyhow!("gate at partition {p} shifts out of the crossbar (distance {distance}, {dir:?})"))?;
            out_mask[q] = true;
        }
    }

    // Select derivation.
    let mut selects = vec![false; k - 1];
    for tr in 0..k - 1 {
        selects[tr] = match dir {
            // Inputs left: isolate when the left neighbour already emitted
            // its output, or the right neighbour starts a new gate.
            Direction::InputsLeft => out_mask[tr] || in_mask[tr + 1],
            Direction::OutputsLeft => in_mask[tr] || out_mask[tr + 1],
        };
    }
    Ok(Expansion { in_mask, out_mask, selects })
}

/// Hardware cost of the minimal periphery's pattern logic: two `k`-wide
/// barrel shifters for `p_start`/`p_end`, a period decoder, and the distance
/// shifter — all on width `k`, not `n`.
pub fn gate_cost(k: usize) -> usize {
    let lk = (k as f64).log2().ceil() as usize;
    // Three barrel shifters (k muxes per stage, log2 k stages, ~3 gates/mux)
    // plus a log2(k)-to-k period decoder.
    3 * (k * lk * 3) + (k * (lk.saturating_sub(1)) + lk)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_pattern() {
        // d=0, T=1, full range: every partition an in-place gate;
        // all transistors isolate.
        let e = expand(&RangeParams { p_start: 0, p_end: 7, t: 1, distance: 0, dir: Direction::InputsLeft }, 8).unwrap();
        assert!(e.in_mask.iter().all(|&b| b));
        assert_eq!(e.in_mask, e.out_mask);
        assert!(e.selects.iter().all(|&b| b));
    }

    #[test]
    fn fig2c_pattern() {
        // d=1, T=2: gates 0->1, 2->3, 4->5, 6->7.
        let e = expand(&RangeParams { p_start: 0, p_end: 6, t: 2, distance: 1, dir: Direction::InputsLeft }, 8).unwrap();
        assert_eq!(e.in_mask, vec![true, false, true, false, true, false, true, false]);
        assert_eq!(e.out_mask, vec![false, true, false, true, false, true, false, true]);
        // Conducting inside each pair, isolating between pairs.
        assert_eq!(e.selects, vec![false, true, false, true, false, true, false]);
    }

    #[test]
    fn serial_gate_with_intermediates() {
        // Single gate partition 2 -> 5 (distance 3).
        let e = expand(&RangeParams { p_start: 2, p_end: 2, t: 4, distance: 3, dir: Direction::InputsLeft }, 8).unwrap();
        assert_eq!(e.in_mask[2], true);
        assert_eq!(e.out_mask[5], true);
        // Section [2, 5] conducting; isolated at 1|2 and 5|6.
        assert_eq!(e.selects, vec![false, true, false, false, false, true, false]);
    }

    #[test]
    fn leftward_direction() {
        // d=1 leftward: gates 1->0, 3->2, 5->4, 7->6.
        let e = expand(&RangeParams { p_start: 1, p_end: 7, t: 2, distance: 1, dir: Direction::OutputsLeft }, 8).unwrap();
        assert_eq!(e.in_mask, vec![false, true, false, true, false, true, false, true]);
        assert_eq!(e.out_mask, vec![true, false, true, false, true, false, true, false]);
        assert_eq!(e.selects, vec![false, true, false, true, false, true, false]);
    }

    #[test]
    fn out_of_range_shift_rejected() {
        assert!(expand(&RangeParams { p_start: 6, p_end: 6, t: 4, distance: 3, dir: Direction::InputsLeft }, 8).is_err());
        assert!(expand(&RangeParams { p_start: 1, p_end: 1, t: 4, distance: 2, dir: Direction::OutputsLeft }, 8).is_err());
    }

    #[test]
    fn overlap_guard() {
        // Two gates with T <= d must be rejected.
        assert!(expand(&RangeParams { p_start: 0, p_end: 4, t: 2, distance: 2, dir: Direction::InputsLeft }, 8).is_err());
    }

    #[test]
    fn pattern_cost_scales_with_k_not_n() {
        assert!(gate_cost(32) < 2000, "range generator must stay O(k log k): {}", gate_cost(32));
    }
}
