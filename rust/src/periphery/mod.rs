//! Crossbar periphery: the decoders that turn control messages into applied
//! voltages, and their structural (gate-count) area models.
//!
//! * [`halfgate`] — the functional core: per-partition opcodes + indices +
//!   transistor selects → sections → executed gates (Section 2.2, Figure 3(c),
//!   Figure 4).
//! * [`opcode_gen`] — the standard model's opcode generator: opcodes derived
//!   from transistor selects, per-partition enables and the global direction
//!   (Section 3.2.2, Figure 5 — two 2:1 multiplexers per partition).
//! * [`range_gen`] — the minimal model's pattern generators: the *range
//!   generator* for input opcodes, the distance shifter for output opcodes,
//!   and the transistor-select derivation (Section 4.2).
//! * [`decoder`] / [`area`] — structural CMOS-gate-count models of every
//!   design, including the naive Ω(k²) decoder stack (Figure 3(b)) the
//!   half-gates technique replaces.

pub mod area;
pub mod decoder;
pub mod halfgate;
pub mod opcode_gen;
pub mod range_gen;

pub use halfgate::{reconstruct, reconstruct_typed};
