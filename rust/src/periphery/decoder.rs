//! Structural model of the column-decoder building blocks (Figure 3(a)).
//!
//! A *decoder unit* receives one index and drives a fixed voltage at that
//! bitline. It consists of a CMOS decoder (providing the select lines) plus
//! one analog multiplexer per bitline [4, 17, 19]. A *column decoder* is
//! three decoder units (InA, InB, Out). These counts feed the area
//! comparison of Section 2.2 / 5.3.1.

/// A `w`-bit CMOS decoder (`w → 2^w` one-hot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CmosDecoder {
    /// Address width in bits.
    pub width: usize,
}

impl CmosDecoder {
    pub fn new(width: usize) -> Self {
        Self { width }
    }

    /// Number of one-hot output lines.
    pub fn lines(&self) -> usize {
        1usize << self.width
    }

    /// Two-input-gate equivalents: each of the `2^w` output AND gates costs
    /// `w - 1` two-input gates, plus `w` input inverters.
    pub fn gate_count(&self) -> usize {
        if self.width == 0 {
            return 0;
        }
        self.lines() * (self.width - 1) + self.width
    }
}

/// One decoder unit: a CMOS decoder plus an analog multiplexer per bitline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecoderUnit {
    pub cmos: CmosDecoder,
    /// Bitlines covered (= analog multiplexers).
    pub bitlines: usize,
}

impl DecoderUnit {
    /// Unit addressing `bitlines` columns.
    pub fn for_bitlines(bitlines: usize) -> Self {
        assert!(bitlines.is_power_of_two());
        Self { cmos: CmosDecoder::new(bitlines.trailing_zeros() as usize), bitlines }
    }

    pub fn cmos_gates(&self) -> usize {
        self.cmos.gate_count()
    }

    /// Analog multiplexers (pass structures) — identical across all designs,
    /// as the paper stresses: only the CMOS select logic changes.
    pub fn analog_muxes(&self) -> usize {
        self.bitlines
    }
}

/// A full column decoder: three decoder units (InA, InB, Out).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColumnDecoder {
    pub unit: DecoderUnit,
}

impl ColumnDecoder {
    pub fn for_bitlines(bitlines: usize) -> Self {
        Self { unit: DecoderUnit::for_bitlines(bitlines) }
    }

    pub fn cmos_gates(&self) -> usize {
        3 * self.unit.cmos_gates()
    }

    pub fn analog_muxes(&self) -> usize {
        3 * self.unit.analog_muxes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmos_decoder_costs() {
        // 10-bit decoder: 1024·9 + 10.
        assert_eq!(CmosDecoder::new(10).gate_count(), 1024 * 9 + 10);
        // 5-bit decoder: 32·4 + 5.
        assert_eq!(CmosDecoder::new(5).gate_count(), 32 * 4 + 5);
    }

    /// Section 2.2: k small decoders use fewer CMOS gates than one big one,
    /// because log2(n/k) < log2(n).
    #[test]
    fn k_small_decoders_cheaper_than_one_big() {
        let n = 1024;
        let k = 32;
        let baseline = ColumnDecoder::for_bitlines(n);
        let per_partition = ColumnDecoder::for_bitlines(n / k);
        assert!(k * per_partition.cmos_gates() < baseline.cmos_gates());
        // Analog mux count is unchanged in aggregate.
        assert_eq!(k * per_partition.analog_muxes(), baseline.analog_muxes());
    }
}
