//! Functional half-gate decoding: from a decoded [`Message`] to the gates the
//! crossbar physically executes.
//!
//! Each partition's decoder applies voltages according to its opcode
//! (Table 1): `V_IN` at its `InA`/`InB` indices, `V_OUT` at its `Out` index.
//! The isolation transistors split the row into *sections* (maximal runs of
//! conducting transistors); the voltages applied inside one section combine
//! into a single stateful gate — each partition only executes *half* a gate
//! and trusts its section peers for the other half.

use crate::crossbar::gate::GateType;
use crate::crossbar::geometry::Geometry;
use crate::isa::encode::{Message, PartitionFields};
use crate::isa::opcode::Opcode;
use crate::isa::operation::{GateOp, Operation};
use crate::periphery::{opcode_gen, range_gen};
use anyhow::{bail, ensure, Result};

/// Split partitions `0..k` into sections at the non-conducting transistors.
/// `selects[t] == true` means the transistor between partitions `t` and
/// `t+1` is non-conducting (isolating).
pub fn sections_from_selects(selects: &[bool]) -> Vec<(usize, usize)> {
    let k = selects.len() + 1;
    let mut sections = Vec::new();
    let mut lo = 0usize;
    for t in 0..k - 1 {
        if selects[t] {
            sections.push((lo, t));
            lo = t + 1;
        }
    }
    sections.push((lo, k - 1));
    sections
}

/// Compose the `(InA, InB, Out)` columns of one section into the executed
/// gate of wire class `class`. `NOR(a, a)` is physically a `NOT` — the one
/// identity the gate-type-free NOT/NOR formats rely on; every other class
/// keeps its two inputs as decoded (e.g. `OR(a, a)` is the copy gate).
fn compose_gate(class: GateType, ca: usize, cb: usize, co: usize) -> GateOp {
    if class == GateType::Nor && ca == cb {
        GateOp::not(ca, co)
    } else {
        GateOp { gate: class, ins: vec![ca, cb], out: co }
    }
}

/// Reconstruct the executed operation from per-partition decoder fields and
/// transistor selects — the shared back-end of all three designs (NOT/NOR
/// gate set; [`reconstruct_from_fields_typed`] is the general form).
pub fn reconstruct_from_fields(parts: &[PartitionFields], selects: &[bool], geom: &Geometry) -> Result<Operation> {
    reconstruct_from_fields_typed(GateType::Nor, parts, selects, geom)
}

/// Reconstruct the executed operation for an arbitrary wire class (the
/// gate-type field decoded by [`crate::isa::encode::decode_with`]): the
/// section/half-gate composition is class-independent, only the gate
/// function applied inside each section changes.
pub fn reconstruct_from_fields_typed(class: GateType, parts: &[PartitionFields], selects: &[bool], geom: &Geometry) -> Result<Operation> {
    ensure!(parts.len() == geom.k, "expected {} partition field sets, got {}", geom.k, parts.len());
    ensure!(selects.len() == geom.k - 1, "expected {} transistor selects, got {}", geom.k - 1, selects.len());
    let mut gates = Vec::new();
    for (lo, hi) in sections_from_selects(selects) {
        let mut a: Option<usize> = None; // absolute column receiving V_IN via InA
        let mut b: Option<usize> = None;
        let mut o: Option<usize> = None;
        for p in lo..=hi {
            let f = &parts[p];
            if f.opcode.in_a {
                ensure!(a.is_none(), "two InA half-gates in section [{lo}, {hi}]");
                a = Some(geom.col(p, f.ia));
            }
            if f.opcode.in_b {
                ensure!(b.is_none(), "two InB half-gates in section [{lo}, {hi}]");
                b = Some(geom.col(p, f.ib));
            }
            if f.opcode.out {
                ensure!(o.is_none(), "two Out half-gates in section [{lo}, {hi}]");
                o = Some(geom.col(p, f.io));
            }
        }
        match (a, b, o) {
            (None, None, None) => continue, // idle section
            (Some(ca), Some(cb), Some(co)) => {
                ensure!(co != ca && co != cb, "output column {co} aliases a gate input in section [{lo}, {hi}]");
                gates.push(compose_gate(class, ca, cb, co));
            }
            _ => bail!("dangling half-gate in section [{lo}, {hi}]: InA={a:?} InB={b:?} Out={o:?} do not compose into a valid gate"),
        }
    }
    ensure!(!gates.is_empty(), "message decodes to no gates");
    Ok(Operation::Gates(gates))
}

/// Decode a [`Message`] into the operation the crossbar executes (NOT/NOR
/// gate set; [`reconstruct_typed`] is the general form).
///
/// This is the functional model of the periphery of Figure 3(c) (unlimited),
/// Figure 5 (standard) and Section 4.2 (minimal).
pub fn reconstruct(msg: &Message, geom: &Geometry) -> Result<Operation> {
    reconstruct_typed(GateType::Nor, msg, geom)
}

/// Decode a [`Message`] of wire class `class` into the operation the
/// crossbar executes. `class` comes from the message's gate-type field
/// ([`crate::isa::encode::decode_with`]); for the NOT/NOR gate set it is
/// always `Nor` and this is exactly [`reconstruct`].
pub fn reconstruct_typed(class: GateType, msg: &Message, geom: &Geometry) -> Result<Operation> {
    match msg {
        Message::Baseline { ia, ib, io } => {
            ensure!(*ia < geom.n && *ib < geom.n && *io < geom.n, "baseline index out of range");
            ensure!(*io != *ia && *io != *ib, "baseline output aliases an input");
            Ok(Operation::serial(compose_gate(class, *ia, *ib, *io)))
        }
        Message::Unlimited { parts, selects } => reconstruct_from_fields_typed(class, parts, selects, geom),
        Message::Standard { ia, ib, io, enables, selects, dir } => {
            ensure!(enables.len() == geom.k, "expected {} enables", geom.k);
            let opcodes = opcode_gen::generate(enables, selects, *dir)?;
            let parts: Vec<PartitionFields> =
                opcodes.into_iter().map(|opcode| PartitionFields { ia: *ia, ib: *ib, io: *io, opcode }).collect();
            reconstruct_from_fields_typed(class, &parts, selects, geom)
        }
        Message::Minimal { ia, ib, io, p_start, p_end, t, distance, dir } => {
            let params = range_gen::RangeParams { p_start: *p_start, p_end: *p_end, t: *t, distance: *distance, dir: *dir };
            let expansion = range_gen::expand(&params, geom.k)?;
            let parts: Vec<PartitionFields> = (0..geom.k)
                .map(|p| PartitionFields {
                    ia: *ia,
                    ib: *ib,
                    io: *io,
                    opcode: Opcode { in_a: expansion.in_mask[p], in_b: expansion.in_mask[p], out: expansion.out_mask[p] },
                })
                .collect();
            reconstruct_from_fields_typed(class, &parts, &expansion.selects, geom)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::gate::GateSet;
    use crate::isa::encode::{decode, encode};
    use crate::isa::models::ModelKind;
    use crate::isa::operation::Direction;

    fn geom() -> Geometry {
        Geometry::new(256, 8, 8).unwrap()
    }

    #[test]
    fn sections_split_correctly() {
        // selects between 8 partitions: isolate after p1 and p4.
        let selects = [false, true, false, false, true, false, false];
        assert_eq!(sections_from_selects(&selects), vec![(0, 1), (2, 4), (5, 7)]);
        let all = [true; 7];
        assert_eq!(sections_from_selects(&all).len(), 8);
        let none = [false; 7];
        assert_eq!(sections_from_selects(&none), vec![(0, 7)]);
    }

    /// Figure 4: the opcode assignment for the operation of Figure 2(d),
    /// decoded back into gates.
    #[test]
    fn figure4_opcode_assignment() {
        let g = geom();
        // Gates: d=0 in p0; p2 -> p3 (half-gate pair); d=0 in p5.
        let op = Operation::Gates(vec![
            GateOp::nor(g.col(0, 0), g.col(0, 1), g.col(0, 3)),
            GateOp::nor(g.col(2, 0), g.col(2, 1), g.col(3, 3)),
            GateOp::nor(g.col(5, 0), g.col(5, 1), g.col(5, 3)),
        ]);
        let bits = encode(ModelKind::Unlimited, &op, &g).unwrap();
        let msg = decode(ModelKind::Unlimited, &bits, &g).unwrap();
        let Message::Unlimited { ref parts, .. } = msg else { panic!() };
        assert_eq!(parts[0].opcode, Opcode::FULL); //   111
        assert_eq!(parts[1].opcode, Opcode::IDLE); //   000
        assert_eq!(parts[2].opcode, Opcode::INPUTS); // 110 (half-gate)
        assert_eq!(parts[3].opcode, Opcode::OUTPUT); // 001 (half-gate)
        assert_eq!(parts[5].opcode, Opcode::FULL);
        let rec = reconstruct(&msg, &g).unwrap();
        assert_eq!(rec.normalized(), op.normalized());
    }

    #[test]
    fn full_pipeline_roundtrip_all_models() {
        let g = geom();
        let cases = vec![
            (vec![ModelKind::Baseline, ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal],
             Operation::serial(GateOp::nor(g.col(1, 2), g.col(1, 7), g.col(6, 9)))),
            (vec![ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal],
             Operation::Gates((0..8).map(|p| GateOp::nor(g.col(p, 0), g.col(p, 1), g.col(p, 3))).collect())),
            (vec![ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal],
             Operation::Gates(vec![
                 GateOp::not(g.col(0, 5), g.col(1, 9)),
                 GateOp::not(g.col(4, 5), g.col(5, 9)),
             ])),
        ];
        for (models, op) in cases {
            for m in models {
                m.check(&op, &g, GateSet::NotNor).unwrap();
                let bits = encode(m, &op, &g).unwrap();
                let msg = decode(m, &bits, &g).unwrap();
                let rec = reconstruct(&msg, &g).unwrap();
                assert_eq!(rec.normalized(), op.normalized(), "model {}", m.name());
            }
        }
    }

    #[test]
    fn dangling_half_gate_rejected() {
        let g = geom();
        // Inputs in p0 but the section [0,0] has no output half.
        let mut parts = vec![PartitionFields { ia: 0, ib: 1, io: 2, opcode: Opcode::IDLE }; 8];
        parts[0].opcode = Opcode::INPUTS;
        let selects = vec![true; 7];
        assert!(reconstruct_from_fields(&parts, &selects, &g).is_err());
    }

    #[test]
    fn conflicting_half_gates_rejected() {
        let g = geom();
        // Two Out halves in one section.
        let mut parts = vec![PartitionFields { ia: 0, ib: 1, io: 2, opcode: Opcode::IDLE }; 8];
        parts[0].opcode = Opcode::INPUTS;
        parts[1].opcode = Opcode::OUTPUT;
        parts[2].opcode = Opcode::OUTPUT;
        let selects = vec![false; 7];
        assert!(reconstruct_from_fields(&parts, &selects, &g).is_err());
    }

    /// Typed wire path: HashPIM XOR/OR cycles encode with the 2-bit
    /// gate-type field and reconstruct to the same gates under every model,
    /// while NOT still rides the NOR class (`ia == ib`).
    #[test]
    fn typed_roundtrip_hashpim() {
        use crate::isa::encode::{decode_with, encode_with};
        let g = geom();
        let mk = |gate: GateType, p: usize| GateOp { gate, ins: vec![g.col(p, 0), g.col(p, 1)], out: g.col(p + 1, 3) };
        let cases = vec![
            Operation::serial(mk(GateType::Xor, 2)),
            Operation::serial(mk(GateType::Or, 0)),
            Operation::Gates(vec![mk(GateType::Xor, 0), mk(GateType::Xor, 4)]),
            Operation::Gates(vec![GateOp::not(g.col(0, 5), g.col(1, 9)), GateOp::not(g.col(4, 5), g.col(5, 9))]),
            // OR(a, a): the copy gate — must NOT fold to NOT.
            Operation::serial(GateOp { gate: GateType::Or, ins: vec![g.col(1, 2), g.col(1, 2)], out: g.col(2, 6) }),
        ];
        let gs = crate::crossbar::gate::GateSet::HashPim;
        for op in cases {
            for m in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
                m.check(&op, &g, gs).unwrap();
                let bits = encode_with(m, &op, &g, gs).unwrap();
                let (class, msg) = decode_with(m, &bits, &g, gs).unwrap();
                let rec = reconstruct_typed(class, &msg, &g).unwrap();
                assert_eq!(rec.normalized(), op.normalized(), "model {}", m.name());
            }
        }
    }

    #[test]
    fn minimal_periodic_reconstruction() {
        let g = geom();
        let msg = Message::Minimal { ia: 0, ib: 1, io: 3, p_start: 0, p_end: 6, t: 2, distance: 1, dir: Direction::InputsLeft };
        let rec = reconstruct(&msg, &g).unwrap();
        let expect = Operation::Gates(
            (0..4).map(|j| GateOp::nor(g.col(2 * j, 0), g.col(2 * j, 1), g.col(2 * j + 1, 3))).collect(),
        );
        assert_eq!(rec.normalized(), expect.normalized());
    }
}
