//! Fault injection: stuck-at faults in memristor cells (the reliability
//! concern of the authors' companion work [13], *Making Memristive
//! Processing-in-Memory Reliable*). Used by the failure-injection tests to
//! show the architectural counters and result verification catch silent
//! data corruption.

use crate::backend::PimBackend;
use crate::crossbar::crossbar::Crossbar;
use crate::crossbar::state::BitMatrix;
use anyhow::{ensure, Result};

/// A stuck-at fault at one memristor cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StuckAt {
    pub row: usize,
    pub col: usize,
    pub value: bool,
}

/// A fault map applied after every cycle (stuck cells override whatever the
/// gate or write produced — the physical behaviour of a stuck device).
#[derive(Debug, Clone, Default)]
pub struct FaultMap {
    pub faults: Vec<StuckAt>,
}

impl FaultMap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stuck(mut self, row: usize, col: usize, value: bool) -> Self {
        self.faults.push(StuckAt { row, col, value });
        self
    }

    /// Pseudo-random fault population at a given cell failure rate.
    pub fn random(rows: usize, cols: usize, rate: f64, seed: u64) -> Self {
        let mut s = seed.max(1);
        let mut faults = Vec::new();
        let threshold = (rate * u64::MAX as f64) as u64;
        for row in 0..rows {
            for col in 0..cols {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                if s < threshold {
                    faults.push(StuckAt { row, col, value: s & 1 == 1 });
                }
            }
        }
        Self { faults }
    }

    /// Force the stuck values into the state.
    pub fn apply(&self, state: &mut BitMatrix) -> Result<()> {
        for f in &self.faults {
            ensure!(f.row < state.rows() && f.col < state.cols(), "fault at ({}, {}) outside the array", f.row, f.col);
            state.set(f.row, f.col, f.value);
        }
        Ok(())
    }
}

/// Execute a program on a faulty crossbar: the fault map is re-applied
/// after every cycle (stuck devices never change state). This is a fault
/// *harness* around the backend's per-cycle [`PimBackend::execute`], not an
/// execution path of its own; it stays on the bit-packed crossbar because it
/// needs cheap direct state access between cycles.
pub fn run_with_faults(xb: &mut Crossbar, ops: &[crate::isa::operation::Operation], faults: &FaultMap) -> Result<()> {
    faults.apply(&mut xb.state)?;
    for op in ops {
        xb.execute(op)?;
        faults.apply(&mut xb.state)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::multpim::{build_multpim, MultPimVariant};
    use crate::crossbar::gate::GateSet;
    use crate::crossbar::geometry::Geometry;

    #[test]
    fn fault_free_map_is_identity() {
        let geom = Geometry::new(128, 4, 8).unwrap();
        let mult = build_multpim(geom, MultPimVariant::Plain).unwrap();
        let mut a = Crossbar::new(geom, GateSet::NotNor);
        mult.load(&mut a.state, 0, 9, 13).unwrap();
        let mut b = a.clone();
        a.execute_ops(&mult.program.ops).unwrap();
        run_with_faults(&mut b, &mult.program.ops, &FaultMap::new()).unwrap();
        assert_eq!(a.state, b.state);
    }

    /// A single stuck cell in the datapath corrupts the product — the
    /// failure-injection check that end-to-end verification would catch.
    #[test]
    fn stuck_cell_corrupts_result() {
        let geom = Geometry::new(128, 4, 8).unwrap();
        let mult = build_multpim(geom, MultPimVariant::Plain).unwrap();
        // Stick the partial-product column of partition 1 at 1.
        let faults = FaultMap::new().stuck(0, geom.col(1, crate::algorithms::multpim::intra::PP), true);
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        mult.load(&mut xb.state, 0, 5, 3).unwrap();
        run_with_faults(&mut xb, &mult.program.ops, &faults).unwrap();
        assert_ne!(mult.read_product(&xb.state, 0).unwrap(), 15, "stuck-at fault must corrupt the product");
    }

    /// Faults in unused columns are harmless — the mapping's spare columns
    /// give natural fault tolerance (the premise of remapping in [13]).
    #[test]
    fn fault_in_unused_column_is_harmless() {
        let geom = Geometry::new(128, 4, 8).unwrap();
        let mult = build_multpim(geom, MultPimVariant::Plain).unwrap();
        // intra column 30 is outside the 23-column MultPIM layout.
        let faults = FaultMap::new().stuck(0, geom.col(2, 30), true);
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        mult.load(&mut xb.state, 0, 11, 12).unwrap();
        run_with_faults(&mut xb, &mult.program.ops, &faults).unwrap();
        assert_eq!(mult.read_product(&xb.state, 0).unwrap(), 132);
    }

    #[test]
    fn random_fault_rate_scales() {
        let f0 = FaultMap::random(64, 256, 0.0, 3);
        assert!(f0.faults.is_empty());
        let f1 = FaultMap::random(64, 256, 0.01, 3);
        let expected = (64.0 * 256.0 * 0.01) as usize;
        assert!(f1.faults.len() > expected / 3 && f1.faults.len() < expected * 3, "{} faults", f1.faults.len());
    }

    #[test]
    fn out_of_range_fault_rejected() {
        let geom = Geometry::new(128, 4, 8).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let faults = FaultMap::new().stuck(99, 0, true);
        assert!(faults.apply(&mut xb.state).is_err());
    }
}
