//! Crossbar geometry: bitline count `n`, partition count `k`, row count.

use anyhow::{ensure, Result};

/// Static geometry of a partitioned crossbar.
///
/// `n` bitlines (columns) are divided into `k` evenly-spaced partitions of
/// `m = n/k` bitlines each by `k-1` isolation transistors per row. The paper's
/// headline configuration is `n = 1024`, `k = 32` (m = 32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Geometry {
    /// Number of bitlines (columns). Must be a power of two.
    pub n: usize,
    /// Number of partitions. Must be a power of two dividing `n`.
    pub k: usize,
    /// Number of wordlines (rows); each row computes independently.
    pub rows: usize,
}

impl Geometry {
    /// Create a geometry, validating the paper's structural assumptions.
    pub fn new(n: usize, k: usize, rows: usize) -> Result<Self> {
        ensure!(n.is_power_of_two(), "n={n} must be a power of two");
        ensure!(k.is_power_of_two(), "k={k} must be a power of two");
        ensure!(k >= 1 && k <= n, "k={k} must be in 1..=n ({n})");
        ensure!(n % k == 0, "k={k} must divide n={n}");
        ensure!(n / k >= 4, "partitions narrower than 4 columns (m={}) cannot hold a two-input gate plus scratch", n / k);
        ensure!(rows >= 1, "rows must be >= 1");
        Ok(Self { n, k, rows })
    }

    /// The paper's headline configuration: n=1024, k=32 — routed through
    /// [`Geometry::new`] so even the canned configuration cannot bypass the
    /// structural invariants (`rows = 0` is rejected here too).
    pub fn paper(rows: usize) -> Result<Self> {
        Self::new(1024, 32, rows)
    }

    /// Width of each partition in bitlines (`m = n/k`).
    #[inline]
    pub fn m(&self) -> usize {
        self.n / self.k
    }

    /// Partition index containing absolute column `col`.
    #[inline]
    pub fn partition_of(&self, col: usize) -> usize {
        debug_assert!(col < self.n);
        col / self.m()
    }

    /// Intra-partition index of absolute column `col` (i.e. `col mod m`).
    #[inline]
    pub fn intra(&self, col: usize) -> usize {
        col % self.m()
    }

    /// Absolute column for (`partition`, `intra`) coordinates.
    #[inline]
    pub fn col(&self, partition: usize, intra: usize) -> usize {
        debug_assert!(partition < self.k && intra < self.m());
        partition * self.m() + intra
    }

    /// `log2(n)` — bits to address a bitline (baseline decoder width).
    #[inline]
    pub fn log2_n(&self) -> usize {
        self.n.trailing_zeros() as usize
    }

    /// `log2(k)` — bits to address a partition.
    #[inline]
    pub fn log2_k(&self) -> usize {
        self.k.trailing_zeros() as usize
    }

    /// `log2(m)` — bits to address a column within a partition.
    #[inline]
    pub fn log2_m(&self) -> usize {
        self.m().trailing_zeros() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let g = Geometry::paper(64).unwrap();
        assert_eq!(g.n, 1024);
        assert_eq!(g.k, 32);
        assert_eq!(g.m(), 32);
        assert_eq!(g.log2_n(), 10);
        assert_eq!(g.log2_k(), 5);
        assert_eq!(g.log2_m(), 5);
    }

    #[test]
    fn coordinates_roundtrip() {
        let g = Geometry::new(256, 8, 16).unwrap();
        for col in 0..g.n {
            let (p, i) = (g.partition_of(col), g.intra(col));
            assert_eq!(g.col(p, i), col);
            assert!(p < g.k && i < g.m());
        }
    }

    #[test]
    fn rejects_bad_geometry() {
        assert!(Geometry::new(1000, 32, 64).is_err()); // n not pow2
        assert!(Geometry::new(1024, 3, 64).is_err()); // k not pow2
        assert!(Geometry::new(1024, 2048, 64).is_err()); // k > n
        assert!(Geometry::new(64, 32, 64).is_err()); // m < 4
        assert!(Geometry::new(1024, 32, 0).is_err()); // no rows
    }

    /// Regression: the canned paper configuration used to construct the
    /// struct literally, accepting `rows = 0` that [`Geometry::new`] rejects.
    #[test]
    fn paper_geometry_is_validated() {
        assert!(Geometry::paper(0).is_err());
        assert!(Geometry::paper(1).is_ok());
    }
}
