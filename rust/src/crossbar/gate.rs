//! Stateful-logic gate semantics.
//!
//! MAGIC [12] provides single-cycle NOT and NOR; FELIX [8] extends the set
//! with OR, NAND and Minority3. The paper's evaluation (Section 5) restricts
//! itself to the NOT/NOR implementation of MultPIM "for simplicity", which we
//! mirror with [`GateSet::NotNor`]; [`GateSet::Felix`] is the generalization
//! the paper's footnote 2 describes.

use anyhow::{bail, Result};

/// A single-cycle stateful logic gate type.
///
/// `Init1`/`Init0` model the initialization write that stateful logic
/// requires before executing a gate into an output memristor (MAGIC requires
/// the output pre-set to logical 1). Initialization is a *write* operation,
/// not a stateful gate: it may set any number of columns in one cycle and
/// does not interact with partition isolation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GateType {
    /// `out = NOT(a)` — MAGIC, 1 input.
    Not,
    /// `out = NOR(a, b)` — MAGIC, 2 inputs.
    Nor,
    /// `out = OR(a, b)` — FELIX, 2 inputs.
    Or,
    /// `out = NAND(a, b)` — FELIX, 2 inputs.
    Nand,
    /// `out = AND(a, b)` — FELIX-derived, 2 inputs.
    And,
    /// `out = XOR(a, b)` — the single-cycle X-MAGIC/HashPIM gate, 2 inputs.
    Xor,
    /// `out = Minority3(a, b, c)` — FELIX, 3 inputs.
    Min3,
    /// `out = 1` — initialization write (SET).
    Init1,
    /// `out = 0` — initialization write (RESET).
    Init0,
}

impl GateType {
    /// Number of input columns this gate consumes.
    #[inline]
    pub fn arity(&self) -> usize {
        match self {
            GateType::Not => 1,
            GateType::Nor | GateType::Or | GateType::Nand | GateType::And | GateType::Xor => 2,
            GateType::Min3 => 3,
            GateType::Init1 | GateType::Init0 => 0,
        }
    }

    /// True for initialization writes (not stateful gates).
    #[inline]
    pub fn is_init(&self) -> bool {
        matches!(self, GateType::Init1 | GateType::Init0)
    }

    /// Whether the gate's truth table is symmetric in its inputs. All the
    /// MAGIC/FELIX gates are (NOR, OR, NAND, AND and Minority3 are
    /// input-order invariant), so input order is not observable on the wire
    /// and canonical forms may sort it away.
    #[inline]
    pub fn commutative(&self) -> bool {
        !matches!(self, GateType::Not)
    }

    /// Evaluate the gate on 64 rows at once (one word per column).
    ///
    /// `ins` must hold exactly `arity()` meaningful words.
    #[inline]
    pub fn eval_word(&self, ins: &[u64]) -> u64 {
        match self {
            GateType::Not => !ins[0],
            GateType::Nor => !(ins[0] | ins[1]),
            GateType::Or => ins[0] | ins[1],
            GateType::Nand => !(ins[0] & ins[1]),
            GateType::And => ins[0] & ins[1],
            GateType::Xor => ins[0] ^ ins[1],
            GateType::Min3 => {
                let (a, b, c) = (ins[0], ins[1], ins[2]);
                !((a & b) | (a & c) | (b & c))
            }
            GateType::Init1 => !0u64,
            GateType::Init0 => 0u64,
        }
    }

    /// Evaluate on single-bit booleans (used by the pure-semantics oracle in
    /// unit tests; the simulator itself uses [`GateType::eval_word`]).
    pub fn eval_bool(&self, ins: &[bool]) -> bool {
        let words: Vec<u64> = ins.iter().map(|&b| if b { !0 } else { 0 }).collect();
        self.eval_word(&words) & 1 == 1
    }
}

/// The gate set a crossbar supports; restricts which operations validate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GateSet {
    /// MAGIC NOT/NOR only — the paper's evaluation configuration.
    NotNor,
    /// The HashPIM configuration: MAGIC NOT/NOR plus FELIX OR and the
    /// single-cycle XOR the SHA-3 datapath is built from.
    HashPim,
    /// FELIX extension: NOT/NOR/OR/NAND/AND/Xor/Min3 (footnote 2 of the
    /// paper).
    Felix,
}

impl GateSet {
    /// Check whether `gate` is executable under this gate set.
    pub fn check(&self, gate: GateType) -> Result<()> {
        if gate.is_init() {
            return Ok(());
        }
        match self {
            GateSet::NotNor => match gate {
                GateType::Not | GateType::Nor => Ok(()),
                other => bail!("gate {other:?} not available in the NOT/NOR gate set"),
            },
            GateSet::HashPim => match gate {
                GateType::Not | GateType::Nor | GateType::Or | GateType::Xor => Ok(()),
                other => bail!("gate {other:?} not available in the HashPIM NOT/NOR/OR/XOR gate set"),
            },
            GateSet::Felix => Ok(()),
        }
    }

    /// Number of distinct (non-init) gate types, for control-message sizing.
    pub fn num_gate_types(&self) -> usize {
        match self {
            // NOT is NOR with InA = InB, so a single opcode suffices — this is
            // why the paper's message formulas carry no gate-type field.
            GateSet::NotNor => 1,
            GateSet::HashPim => 3,
            GateSet::Felix => 7,
        }
    }

    /// Maximum gate arity (2 for the paper's configuration, 3 with Min3).
    pub fn max_arity(&self) -> usize {
        match self {
            GateSet::NotNor | GateSet::HashPim => 2,
            GateSet::Felix => 3,
        }
    }

    /// The *wire classes* of this gate set: the distinct two-input gate
    /// functions a control message must be able to name. NOT is NOR with
    /// `InA = InB` (the paper's formats carry no gate-type field at all),
    /// so it folds into the NOR class; every other gate is its own class.
    /// `Min3` is 3-input and has no half-gate wire encoding — programs
    /// using it stay on the direct path (the encoder reports V030).
    pub fn wire_classes(&self) -> &'static [GateType] {
        match self {
            GateSet::NotNor => &[GateType::Nor],
            GateSet::HashPim => &[GateType::Nor, GateType::Or, GateType::Xor],
            GateSet::Felix => &[GateType::Nor, GateType::Or, GateType::Nand, GateType::And, GateType::Xor],
        }
    }

    /// Width of the per-cycle gate-type field in this gate set's control
    /// messages: `ceil(log2(#wire classes))`. Zero for NOT/NOR — the
    /// paper's published format lengths (30/607/79/36 bits) are preserved
    /// bit-for-bit; richer gate sets pay `wire_type_bits` extra bits per
    /// message (mirroring the FELIX extension costing in
    /// `algorithms::felix::extended_message_bits`).
    pub fn wire_type_bits(&self) -> usize {
        let n = self.wire_classes().len();
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }

    /// The wire class of `gate` under this set (`None` when the gate is not
    /// wire-encodable here — not in the set, init pseudo-gate, or `Min3`).
    pub fn wire_class_of(&self, gate: GateType) -> Option<GateType> {
        let class = match gate {
            GateType::Not => GateType::Nor,
            g => g,
        };
        self.wire_classes().contains(&class).then_some(class)
    }

    /// Index of `gate`'s wire class in the gate-type field encoding.
    pub fn wire_class_index(&self, gate: GateType) -> Option<usize> {
        let class = self.wire_class_of(gate)?;
        self.wire_classes().iter().position(|&c| c == class)
    }

    /// Decode a gate-type field value back to its wire class.
    pub fn wire_class_from_index(&self, index: usize) -> Result<GateType> {
        self.wire_classes()
            .get(index)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("gate-type field value {index} out of range for {self:?} ({} classes)", self.wire_classes().len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truth_tables() {
        let f = false;
        let t = true;
        assert_eq!(GateType::Nor.eval_bool(&[f, f]), t);
        assert_eq!(GateType::Nor.eval_bool(&[t, f]), f);
        assert_eq!(GateType::Nor.eval_bool(&[f, t]), f);
        assert_eq!(GateType::Nor.eval_bool(&[t, t]), f);
        assert_eq!(GateType::Not.eval_bool(&[f]), t);
        assert_eq!(GateType::Not.eval_bool(&[t]), f);
        assert_eq!(GateType::Nand.eval_bool(&[t, t]), f);
        assert_eq!(GateType::And.eval_bool(&[t, t]), t);
        assert_eq!(GateType::Or.eval_bool(&[f, t]), t);
        // Minority3 = NOT(majority)
        assert_eq!(GateType::Min3.eval_bool(&[t, t, f]), f);
        assert_eq!(GateType::Min3.eval_bool(&[t, f, f]), t);
        assert_eq!(GateType::Min3.eval_bool(&[f, f, f]), t);
        assert_eq!(GateType::Min3.eval_bool(&[t, t, t]), f);
    }

    #[test]
    fn not_is_nor_with_equal_inputs() {
        for v in [0u64, !0u64, 0xdeadbeefdeadbeef] {
            assert_eq!(GateType::Not.eval_word(&[v]), GateType::Nor.eval_word(&[v, v]));
        }
    }

    #[test]
    fn commutativity() {
        assert!(!GateType::Not.commutative());
        for g in [GateType::Nor, GateType::Or, GateType::Nand, GateType::And] {
            assert!(g.commutative());
            for (a, b) in [(false, true), (true, false), (true, true), (false, false)] {
                assert_eq!(g.eval_bool(&[a, b]), g.eval_bool(&[b, a]), "{g:?}");
            }
        }
        assert!(GateType::Min3.commutative());
    }

    #[test]
    fn gate_set_restrictions() {
        assert!(GateSet::NotNor.check(GateType::Nor).is_ok());
        assert!(GateSet::NotNor.check(GateType::Init1).is_ok());
        assert!(GateSet::NotNor.check(GateType::Min3).is_err());
        assert!(GateSet::Felix.check(GateType::Min3).is_ok());
        assert_eq!(GateSet::NotNor.num_gate_types(), 1);
        assert_eq!(GateSet::NotNor.max_arity(), 2);
    }
}
