//! Persistent per-row wear tracking for a memristive crossbar.
//!
//! Stateful logic physically switches memristors on every gate, so endurance
//! is a serving-time constraint, not an offline concern. The [`WearMap`]
//! accumulates the crossbar's exact per-row `switch_events` attribution across
//! batches — wear is physical, so it survives `clear_rows` and every batch
//! boundary — and doubles as the row-health ledger: rows found stuck-at are
//! quarantined here and excluded from all future placements.
//!
//! Placement itself also lives here: [`WearMap::assign_rows`] turns a batch of
//! segment spans into concrete row lists, either front-packed (the historical
//! layout, used when wear leveling is disabled) or coldest-rows-first. Because
//! column gates never cross rows and every batch starts from cleared operand
//! rows, a segment's values and per-row switch counts depend only on its own
//! loaded data — results and metrics are invariant under row placement, which
//! is what makes both leveling and stuck-row remapping transparent to jobs.

use std::fmt;

/// Persistent per-row switch totals plus the quarantine ledger for one crossbar.
#[derive(Debug, Clone)]
pub struct WearMap {
    switches: Vec<u64>,
    quarantined: Vec<bool>,
}

impl WearMap {
    /// A fresh map for a crossbar with `rows` rows: zero wear, nothing quarantined.
    pub fn new(rows: usize) -> Self {
        Self { switches: vec![0; rows], quarantined: vec![false; rows] }
    }

    /// Number of rows tracked.
    pub fn rows(&self) -> usize {
        self.switches.len()
    }

    /// Fold a per-row switch snapshot (as produced by the crossbar's row
    /// switch tracker since its last reset) into the persistent totals.
    /// Snapshots shorter than the map only touch the rows they cover.
    pub fn absorb(&mut self, snapshot: &[u64]) {
        for (acc, &delta) in self.switches.iter_mut().zip(snapshot) {
            *acc += delta;
        }
    }

    /// Add `n` switch events to a single row.
    pub fn record(&mut self, row: usize, n: u64) {
        if let Some(acc) = self.switches.get_mut(row) {
            *acc += n;
        }
    }

    /// Accumulated switch events for one row (0 for out-of-range rows).
    pub fn wear(&self, row: usize) -> u64 {
        self.switches.get(row).copied().unwrap_or(0)
    }

    /// The most-worn row's total — the endurance-limiting quantity.
    pub fn max_wear(&self) -> u64 {
        self.switches.iter().copied().max().unwrap_or(0)
    }

    /// Sum of all per-row switch totals.
    pub fn total_wear(&self) -> u64 {
        self.switches.iter().sum()
    }

    /// Mean per-row switch total (0.0 for an empty map).
    pub fn mean_wear(&self) -> f64 {
        if self.switches.is_empty() {
            0.0
        } else {
            self.total_wear() as f64 / self.switches.len() as f64
        }
    }

    /// Gini coefficient of the per-row wear distribution: 0.0 when wear is
    /// perfectly even (or all-zero), approaching 1.0 when a single row absorbs
    /// everything. The wear-leveling ablation reads directly off this number.
    pub fn gini(&self) -> f64 {
        let mut xs = self.switches.clone();
        xs.sort_unstable();
        let n = xs.len();
        let total: u128 = xs.iter().map(|&x| x as u128).sum();
        if n == 0 || total == 0 {
            return 0.0;
        }
        let mut weighted: u128 = 0;
        for (i, &x) in xs.iter().enumerate() {
            weighted += (i as u128 + 1) * x as u128;
        }
        (2.0 * weighted as f64) / (n as f64 * total as f64) - (n as f64 + 1.0) / n as f64
    }

    /// Quarantine a row (idempotent). Returns `true` when the row was newly
    /// quarantined, `false` when it was already out of service or out of range.
    pub fn quarantine(&mut self, row: usize) -> bool {
        match self.quarantined.get_mut(row) {
            Some(q) if !*q => {
                *q = true;
                true
            }
            _ => false,
        }
    }

    /// Whether a row is quarantined (out-of-range rows read as healthy).
    pub fn is_quarantined(&self, row: usize) -> bool {
        self.quarantined.get(row).copied().unwrap_or(false)
    }

    /// Rows currently quarantined, ascending.
    pub fn quarantined_rows(&self) -> Vec<usize> {
        (0..self.quarantined.len()).filter(|&r| self.quarantined[r]).collect()
    }

    /// Number of rows still in service.
    pub fn healthy_rows(&self) -> usize {
        self.quarantined.iter().filter(|&&q| !q).count()
    }

    /// Place a batch of segment spans onto healthy rows. Returns one ascending
    /// row list per span, or `None` when the healthy capacity cannot hold the
    /// batch (the caller fails the segments typed, with `RowQuarantined`).
    ///
    /// With `leveling` off and nothing quarantined this reproduces the
    /// historical front-packed layout exactly (rows `0..total` in order).
    /// With `leveling` on, healthy rows are consumed coldest-first (ties
    /// broken by row index), spreading switch events across the array.
    pub fn assign_rows(&self, spans: &[usize], leveling: bool) -> Option<Vec<Vec<usize>>> {
        let total: usize = spans.iter().sum();
        let mut healthy: Vec<usize> = (0..self.switches.len()).filter(|&r| !self.quarantined[r]).collect();
        if total > healthy.len() {
            return None;
        }
        if leveling {
            healthy.sort_by_key(|&r| (self.switches[r], r));
        }
        let mut next = healthy.into_iter();
        Some(
            spans
                .iter()
                .map(|&span| {
                    let mut rows: Vec<usize> = next.by_ref().take(span).collect();
                    rows.sort_unstable();
                    rows
                })
                .collect(),
        )
    }

    /// Condense the map into the endurance-horizon report carried by
    /// `ServiceStats`. `elapsed_secs` is the observation window (used to turn
    /// the observed peak switch rate into a projected time-to-first-failure);
    /// `budget` is the per-row endurance budget in switch events, if one is
    /// configured.
    pub fn summarize(&self, elapsed_secs: f64, budget: Option<u64>) -> WearSummary {
        let max = self.max_wear();
        let budget_raw = budget.unwrap_or(0);
        let ttff = match budget {
            Some(b) if max > 0 && elapsed_secs > 0.0 => {
                let remaining = b.saturating_sub(max) as f64;
                let rate = max as f64 / elapsed_secs;
                remaining / rate
            }
            _ => f64::INFINITY,
        };
        WearSummary {
            rows: self.rows() as u64,
            max_row_wear: max,
            mean_row_wear: self.mean_wear(),
            wear_gini: self.gini(),
            quarantined_rows: self.quarantined.iter().filter(|&&q| q).count() as u64,
            endurance_budget: budget_raw,
            projected_ttff_secs: ttff,
        }
    }
}

/// Endurance-horizon report for one bank (or, after [`WearSummary::merge`],
/// a whole fleet): how unevenly wear is distributed, how close the hottest
/// row is to the endurance budget, and the projected time to first row
/// failure at the observed switch rate.
#[derive(Debug, Clone, Copy)]
pub struct WearSummary {
    /// Rows covered by the summary.
    pub rows: u64,
    /// Switch events on the most-worn row.
    pub max_row_wear: u64,
    /// Mean per-row switch events.
    pub mean_row_wear: f64,
    /// Gini coefficient of the per-row wear distribution (0 = even).
    pub wear_gini: f64,
    /// Rows taken out of service by stuck-at quarantine.
    pub quarantined_rows: u64,
    /// Configured per-row endurance budget in switch events (0 = unset).
    pub endurance_budget: u64,
    /// Projected seconds until the hottest row exhausts the budget at the
    /// observed switch rate; infinite when no budget is set or no wear has
    /// accumulated yet.
    pub projected_ttff_secs: f64,
}

impl Default for WearSummary {
    fn default() -> Self {
        Self {
            rows: 0,
            max_row_wear: 0,
            mean_row_wear: 0.0,
            wear_gini: 0.0,
            quarantined_rows: 0,
            endurance_budget: 0,
            projected_ttff_secs: f64::INFINITY,
        }
    }
}

impl WearSummary {
    /// Fold another bank's summary into this one. Means are row-weighted;
    /// `max_row_wear` takes the fleet-wide maximum; the Gini takes the worse
    /// (larger) of the two — a conservative bound, since the exact fleet Gini
    /// needs the raw distributions; the horizon takes the earliest projected
    /// failure; a zero (unset) budget defers to the other side's.
    pub fn merge(&mut self, other: &WearSummary) {
        let total_rows = self.rows + other.rows;
        if total_rows > 0 {
            self.mean_row_wear =
                (self.mean_row_wear * self.rows as f64 + other.mean_row_wear * other.rows as f64) / total_rows as f64;
        }
        self.rows = total_rows;
        self.max_row_wear = self.max_row_wear.max(other.max_row_wear);
        self.wear_gini = self.wear_gini.max(other.wear_gini);
        self.quarantined_rows += other.quarantined_rows;
        self.endurance_budget = match (self.endurance_budget, other.endurance_budget) {
            (0, b) => b,
            (a, 0) => a,
            (a, b) => a.min(b),
        };
        self.projected_ttff_secs = self.projected_ttff_secs.min(other.projected_ttff_secs);
    }
}

impl fmt::Display for WearSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "max row wear {} (mean {:.1}, gini {:.3}), {} row(s) quarantined",
            self.max_row_wear, self.mean_row_wear, self.wear_gini, self.quarantined_rows
        )?;
        if self.endurance_budget > 0 {
            if self.projected_ttff_secs.is_finite() {
                write!(f, ", projected TTFF {:.1}s @ budget {}", self.projected_ttff_secs, self.endurance_budget)?;
            } else {
                write!(f, ", no wear observed @ budget {}", self.endurance_budget)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_accumulates_across_snapshots() {
        let mut map = WearMap::new(4);
        map.absorb(&[1, 2, 3, 4]);
        map.absorb(&[10, 0, 0, 0]);
        assert_eq!(map.wear(0), 11);
        assert_eq!(map.wear(3), 4);
        assert_eq!(map.max_wear(), 11);
        assert_eq!(map.total_wear(), 20);
        assert!((map.mean_wear() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn gini_zero_when_even_and_high_when_concentrated() {
        let mut even = WearMap::new(4);
        even.absorb(&[5, 5, 5, 5]);
        assert!(even.gini().abs() < 1e-12);

        let mut skew = WearMap::new(4);
        skew.absorb(&[100, 0, 0, 0]);
        assert!((skew.gini() - 0.75).abs() < 1e-12);

        assert_eq!(WearMap::new(4).gini(), 0.0);
    }

    #[test]
    fn quarantine_is_idempotent_and_shrinks_capacity() {
        let mut map = WearMap::new(3);
        assert!(map.quarantine(1));
        assert!(!map.quarantine(1));
        assert!(!map.quarantine(99));
        assert!(map.is_quarantined(1));
        assert_eq!(map.quarantined_rows(), vec![1]);
        assert_eq!(map.healthy_rows(), 2);
    }

    #[test]
    fn assign_rows_front_packs_without_leveling() {
        let map = WearMap::new(8);
        let plan = map.assign_rows(&[3, 2], false).unwrap();
        assert_eq!(plan, vec![vec![0, 1, 2], vec![3, 4]]);
    }

    #[test]
    fn assign_rows_prefers_cold_rows_with_leveling() {
        let mut map = WearMap::new(6);
        map.absorb(&[50, 40, 30, 20, 10, 0]);
        let plan = map.assign_rows(&[2, 2], true).unwrap();
        // Coldest first: rows 5, 4 for the first span, then 3, 2.
        assert_eq!(plan, vec![vec![4, 5], vec![2, 3]]);
    }

    #[test]
    fn assign_rows_skips_quarantined_and_reports_exhaustion() {
        let mut map = WearMap::new(4);
        map.quarantine(0);
        map.quarantine(2);
        let plan = map.assign_rows(&[2], false).unwrap();
        assert_eq!(plan, vec![vec![1, 3]]);
        assert!(map.assign_rows(&[3], false).is_none());
        // Zero-span batches always fit, even at zero capacity.
        map.quarantine(1);
        map.quarantine(3);
        assert_eq!(map.assign_rows(&[0], true).unwrap(), vec![Vec::<usize>::new()]);
    }

    #[test]
    fn summary_projects_horizon_and_merges() {
        let mut map = WearMap::new(4);
        map.absorb(&[100, 50, 0, 0]);
        let s = map.summarize(10.0, Some(1_100));
        assert_eq!(s.max_row_wear, 100);
        // Rate 10 switches/s on the hottest row, 1000 remaining -> 100 s.
        assert!((s.projected_ttff_secs - 100.0).abs() < 1e-9);

        let t = map.summarize(10.0, None);
        assert!(t.projected_ttff_secs.is_infinite());
        assert_eq!(t.endurance_budget, 0);

        let mut merged = s;
        let mut other = WearMap::new(4).summarize(1.0, Some(500));
        other.quarantined_rows = 1;
        merged.merge(&other);
        assert_eq!(merged.rows, 8);
        assert_eq!(merged.max_row_wear, 100);
        assert_eq!(merged.endurance_budget, 500);
        assert_eq!(merged.quarantined_rows, 1);
        assert!((merged.mean_row_wear - 150.0 / 8.0).abs() < 1e-9);
    }
}
