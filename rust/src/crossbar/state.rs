//! Bit-packed crossbar state.
//!
//! Column-major packing: each column (bitline) is a contiguous
//! `ceil(rows/64)`-word bitvector over the rows. A row-parallel column gate
//! (the fundamental stateful-logic primitive) is then a word-wide boolean
//! loop over `rows/64` words — the hot path of the whole simulator.

use crate::crossbar::gate::GateType;
use anyhow::{ensure, Result};

/// A `rows × cols` bit matrix stored column-major in 64-bit words.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    /// Words per column: `ceil(rows / 64)`.
    wpc: usize,
    /// Mask of valid bits in the last word of each column.
    tail_mask: u64,
    data: Vec<u64>,
}

impl BitMatrix {
    /// All-zero matrix.
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows > 0 && cols > 0);
        let wpc = rows.div_ceil(64);
        let rem = rows % 64;
        let tail_mask = if rem == 0 { !0u64 } else { (1u64 << rem) - 1 };
        Self { rows, cols, wpc, tail_mask, data: vec![0; wpc * cols] }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Words backing column `c`.
    #[inline]
    pub fn col(&self, c: usize) -> &[u64] {
        debug_assert!(c < self.cols);
        &self.data[c * self.wpc..(c + 1) * self.wpc]
    }

    /// Mutable words backing column `c`.
    #[inline]
    pub fn col_mut(&mut self, c: usize) -> &mut [u64] {
        debug_assert!(c < self.cols);
        &mut self.data[c * self.wpc..(c + 1) * self.wpc]
    }

    /// Read bit (`r`, `c`).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        (self.data[c * self.wpc + r / 64] >> (r % 64)) & 1 == 1
    }

    /// Write bit (`r`, `c`).
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let w = &mut self.data[c * self.wpc + r / 64];
        if v {
            *w |= 1 << (r % 64);
        } else {
            *w &= !(1 << (r % 64));
        }
    }

    /// Mask applied to the last word of a column (invalid high bits are kept
    /// zero by all mutating operations).
    #[inline]
    fn masked(&self, word_idx: usize, w: u64) -> u64 {
        if word_idx + 1 == self.wpc {
            w & self.tail_mask
        } else {
            w
        }
    }

    /// Shared argument validation of [`BitMatrix::apply_gate`] and
    /// [`BitMatrix::apply_gate_tracked`] — one source of truth, so the
    /// tracked path can never drift from the hot path's checks.
    fn check_gate_args(&self, gate: GateType, ins: &[usize], out: usize) -> Result<()> {
        ensure!(ins.len() == gate.arity(), "gate {gate:?} expects {} inputs, got {}", gate.arity(), ins.len());
        ensure!(out < self.cols, "output column {out} out of range ({})", self.cols);
        for &i in ins {
            ensure!(i < self.cols, "input column {i} out of range ({})", self.cols);
            ensure!(i != out, "stateful gate output column {out} must differ from its inputs");
        }
        Ok(())
    }

    /// Apply a row-parallel stateful gate: `out[r] = gate(ins[0][r], ...)` for
    /// every row `r`, in one simulated cycle.
    ///
    /// Returns the number of memristor *switching events* (bit flips in the
    /// output column), the physical quantity that dominates stateful-logic
    /// energy [19].
    pub fn apply_gate(&mut self, gate: GateType, ins: &[usize], out: usize) -> Result<u64> {
        self.check_gate_args(gate, ins, out)?;
        let wpc = self.wpc;
        let out_off = out * wpc;
        let mut switches = 0u64;
        let mut in_words = [0u64; 3];
        for w in 0..wpc {
            for (slot, &i) in ins.iter().enumerate() {
                in_words[slot] = self.data[i * wpc + w];
            }
            let new = self.masked(w, gate.eval_word(&in_words[..ins.len().max(1)]));
            let old = self.data[out_off + w];
            switches += (new ^ old).count_ones() as u64;
            self.data[out_off + w] = new;
        }
        Ok(switches)
    }

    /// Like [`BitMatrix::apply_gate`], but additionally attributes every
    /// output-bit flip to its row: `row_acc[r]` is incremented once per
    /// switching event in row `r`. This is the exact-attribution path the
    /// coordinator uses to charge each segment of a coalesced row-batch its
    /// own switching energy; the untracked [`BitMatrix::apply_gate`] remains
    /// the count-free simulator hot path.
    pub fn apply_gate_tracked(&mut self, gate: GateType, ins: &[usize], out: usize, row_acc: &mut [u64]) -> Result<u64> {
        ensure!(row_acc.len() >= self.rows, "row accumulator holds {} rows, matrix has {}", row_acc.len(), self.rows);
        self.check_gate_args(gate, ins, out)?;
        let wpc = self.wpc;
        let out_off = out * wpc;
        let mut switches = 0u64;
        let mut in_words = [0u64; 3];
        for w in 0..wpc {
            for (slot, &i) in ins.iter().enumerate() {
                in_words[slot] = self.data[i * wpc + w];
            }
            let new = self.masked(w, gate.eval_word(&in_words[..ins.len().max(1)]));
            let old = self.data[out_off + w];
            let mut diff = new ^ old;
            switches += diff.count_ones() as u64;
            self.data[out_off + w] = new;
            while diff != 0 {
                row_acc[w * 64 + diff.trailing_zeros() as usize] += 1;
                diff &= diff - 1;
            }
        }
        Ok(switches)
    }

    /// Initialization write: set every column in `cols` to `value` in one
    /// cycle (multi-column SET/RESET). Returns switching events.
    pub fn init_columns(&mut self, cols: &[usize], value: bool) -> Result<u64> {
        let mut switches = 0u64;
        for &c in cols {
            ensure!(c < self.cols, "init column {c} out of range ({})", self.cols);
            let wpc = self.wpc;
            for w in 0..wpc {
                let new = self.masked(w, if value { !0u64 } else { 0u64 });
                let old = self.data[c * wpc + w];
                switches += (new ^ old).count_ones() as u64;
                self.data[c * wpc + w] = new;
            }
        }
        Ok(switches)
    }

    /// Per-row-attributed variant of [`BitMatrix::init_columns`] (see
    /// [`BitMatrix::apply_gate_tracked`]).
    pub fn init_columns_tracked(&mut self, cols: &[usize], value: bool, row_acc: &mut [u64]) -> Result<u64> {
        ensure!(row_acc.len() >= self.rows, "row accumulator holds {} rows, matrix has {}", row_acc.len(), self.rows);
        let mut switches = 0u64;
        for &c in cols {
            ensure!(c < self.cols, "init column {c} out of range ({})", self.cols);
            let wpc = self.wpc;
            for w in 0..wpc {
                let new = self.masked(w, if value { !0u64 } else { 0u64 });
                let old = self.data[c * wpc + w];
                let mut diff = new ^ old;
                switches += diff.count_ones() as u64;
                self.data[c * wpc + w] = new;
                while diff != 0 {
                    row_acc[w * 64 + diff.trailing_zeros() as usize] += 1;
                    diff &= diff - 1;
                }
            }
        }
        Ok(switches)
    }

    /// Number of 64-bit words backing each column (`ceil(rows / 64)`) — the
    /// granularity of word-range-parallel batch execution.
    #[inline]
    pub fn words_per_col(&self) -> usize {
        self.wpc
    }

    /// Copy out the sub-matrix holding word rows `w0..w1` of every column
    /// (rows `w0*64 .. min(w1*64, rows)`). Together with
    /// [`BitMatrix::splice_word_range`] this is the split/merge primitive of
    /// word-range-parallel batch execution: stateful logic never crosses
    /// rows, so disjoint word ranges can execute the same operation stream
    /// independently and be merged back bit-exactly.
    pub fn extract_word_range(&self, w0: usize, w1: usize) -> Result<BitMatrix> {
        ensure!(w0 < w1 && w1 <= self.wpc, "word range [{w0}, {w1}) out of range ({} words per column)", self.wpc);
        let rows = (w1 * 64).min(self.rows) - w0 * 64;
        let mut out = BitMatrix::new(rows, self.cols);
        let wpc = self.wpc;
        for c in 0..self.cols {
            let src = &self.data[c * wpc + w0..c * wpc + w1];
            out.data[c * out.wpc..(c + 1) * out.wpc].copy_from_slice(src);
        }
        Ok(out)
    }

    /// Write a chunk extracted with [`BitMatrix::extract_word_range`] back at
    /// word row `w0`, replacing exactly the words the extraction covered.
    pub fn splice_word_range(&mut self, w0: usize, chunk: &BitMatrix) -> Result<()> {
        ensure!(chunk.cols == self.cols, "chunk has {} columns, matrix has {}", chunk.cols, self.cols);
        let w1 = w0 + chunk.wpc;
        ensure!(w1 <= self.wpc, "chunk of {} words at word row {w0} exceeds {} words per column", chunk.wpc, self.wpc);
        ensure!(
            chunk.rows == (w1 * 64).min(self.rows) - w0 * 64,
            "chunk of {} rows does not fill word range [{w0}, {w1}) of a {}-row matrix",
            chunk.rows,
            self.rows
        );
        let wpc = self.wpc;
        for c in 0..self.cols {
            self.data[c * wpc + w0..c * wpc + w1].copy_from_slice(&chunk.data[c * chunk.wpc..(c + 1) * chunk.wpc]);
        }
        Ok(())
    }

    /// Zero every cell of rows `start..end` across all columns, in
    /// word-granular operations — the coordinator's batch-hygiene primitive.
    /// A cleared row range makes per-batch metrics independent of whatever
    /// the bank ran before (the ghost-row fix). No metrics are charged: row
    /// clearing rides the operand write path, which is likewise uncounted.
    pub fn clear_rows(&mut self, start: usize, end: usize) -> Result<()> {
        ensure!(start <= end && end <= self.rows, "row range [{start}, {end}) out of range ({} rows)", self.rows);
        if start == end {
            return Ok(());
        }
        let first_word = start / 64;
        let last_word = (end - 1) / 64;
        for c in 0..self.cols {
            let base = c * self.wpc;
            for w in first_word..=last_word {
                let lo = if w == first_word { start % 64 } else { 0 };
                let hi = if w == last_word { (end - 1) % 64 + 1 } else { 64 };
                let mask = if hi - lo == 64 { !0u64 } else { ((1u64 << (hi - lo)) - 1) << lo };
                self.data[base + w] &= !mask;
            }
        }
        Ok(())
    }

    /// Write an unsigned little-endian bit field into row `r`:
    /// `value` bit `i` lands in column `start + i`.
    pub fn write_field(&mut self, r: usize, start: usize, width: usize, value: u64) -> Result<()> {
        ensure!(width <= 64 && start + width <= self.cols, "field [{start}, {start}+{width}) out of range");
        for i in 0..width {
            self.set(r, start + i, (value >> i) & 1 == 1);
        }
        Ok(())
    }

    /// Read an unsigned little-endian bit field from row `r`.
    pub fn read_field(&self, r: usize, start: usize, width: usize) -> Result<u64> {
        ensure!(width <= 64 && start + width <= self.cols, "field [{start}, {start}+{width}) out of range");
        let mut v = 0u64;
        for i in 0..width {
            if self.get(r, start + i) {
                v |= 1 << i;
            }
        }
        Ok(v)
    }

    /// Write a bit field at (`partition`, `intra`) coordinates where bit `i`
    /// of `value` lands at intra-column `intra` of partition `start_part + i`
    /// (one bit per partition — the MultPIM operand layout).
    pub fn write_strided(&mut self, r: usize, start_col: usize, stride: usize, width: usize, value: u64) -> Result<()> {
        ensure!(width <= 64, "width > 64");
        for i in 0..width {
            let c = start_col + i * stride;
            ensure!(c < self.cols, "strided column {c} out of range");
            self.set(r, c, (value >> i) & 1 == 1);
        }
        Ok(())
    }

    /// Read a strided bit field (see [`BitMatrix::write_strided`]).
    pub fn read_strided(&self, r: usize, start_col: usize, stride: usize, width: usize) -> Result<u64> {
        ensure!(width <= 64, "width > 64");
        let mut v = 0u64;
        for i in 0..width {
            let c = start_col + i * stride;
            ensure!(c < self.cols, "strided column {c} out of range");
            if self.get(r, c) {
                v |= 1 << i;
            }
        }
        Ok(v)
    }

    /// Fill with deterministic pseudo-random bits (xorshift64*), for tests
    /// and benches.
    pub fn fill_random(&mut self, seed: u64) {
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15).max(1);
        for c in 0..self.cols {
            let wpc = self.wpc;
            for w in 0..wpc {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                self.data[c * wpc + w] = self.masked(w, s.wrapping_mul(0x2545f4914f6cdd1d));
            }
        }
    }

    /// Dense `f32` row-major copy of the state (`1.0` / `0.0` per bit) —
    /// the interchange layout of the XLA/Pallas backend.
    pub fn to_f32_row_major(&self) -> Vec<f32> {
        let mut v = vec![0f32; self.rows * self.cols];
        for c in 0..self.cols {
            let col = self.col(c);
            for r in 0..self.rows {
                if (col[r / 64] >> (r % 64)) & 1 == 1 {
                    v[r * self.cols + c] = 1.0;
                }
            }
        }
        v
    }

    /// Inverse of [`BitMatrix::to_f32_row_major`] (values must be 0.0/1.0).
    pub fn from_f32_row_major(rows: usize, cols: usize, v: &[f32]) -> Result<Self> {
        ensure!(v.len() == rows * cols, "expected {} values, got {}", rows * cols, v.len());
        let mut m = Self::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                let x = v[r * cols + c];
                ensure!(x == 0.0 || x == 1.0, "non-binary value {x} at ({r}, {c})");
                if x == 1.0 {
                    m.set(r, c, true);
                }
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_roundtrip() {
        let mut m = BitMatrix::new(70, 8); // non-multiple-of-64 rows
        m.set(0, 0, true);
        m.set(69, 7, true);
        m.set(64, 3, true);
        assert!(m.get(0, 0) && m.get(69, 7) && m.get(64, 3));
        assert!(!m.get(1, 0) && !m.get(68, 7));
        m.set(69, 7, false);
        assert!(!m.get(69, 7));
    }

    #[test]
    fn nor_matches_scalar_semantics() {
        let mut m = BitMatrix::new(130, 4);
        m.fill_random(42);
        let a: Vec<bool> = (0..130).map(|r| m.get(r, 0)).collect();
        let b: Vec<bool> = (0..130).map(|r| m.get(r, 1)).collect();
        m.apply_gate(GateType::Nor, &[0, 1], 2).unwrap();
        for r in 0..130 {
            assert_eq!(m.get(r, 2), !(a[r] | b[r]), "row {r}");
        }
    }

    #[test]
    fn switching_energy_counts_flips() {
        let mut m = BitMatrix::new(64, 3);
        // a = all ones, b = all ones -> NOR = 0; out starts at 1 (init).
        m.init_columns(&[0, 1, 2], true).unwrap();
        let sw = m.apply_gate(GateType::Nor, &[0, 1], 2).unwrap();
        assert_eq!(sw, 64); // all 64 output bits flipped 1 -> 0
        let sw2 = m.apply_gate(GateType::Nor, &[0, 1], 2).unwrap();
        assert_eq!(sw2, 0); // already 0
    }

    #[test]
    fn init_tail_masked() {
        let mut m = BitMatrix::new(65, 1);
        let sw = m.init_columns(&[0], true).unwrap();
        assert_eq!(sw, 65); // only valid bits counted
    }

    #[test]
    fn rejects_in_place_gate() {
        let mut m = BitMatrix::new(64, 2);
        assert!(m.apply_gate(GateType::Not, &[0], 0).is_err());
    }

    #[test]
    fn tracked_gate_matches_untracked_and_attributes_rows() {
        let mut a = BitMatrix::new(130, 4);
        a.fill_random(11);
        let mut b = a.clone();
        let sw_plain = a.apply_gate(GateType::Nor, &[0, 1], 2).unwrap();
        let mut rows = vec![0u64; 130];
        let sw_tracked = b.apply_gate_tracked(GateType::Nor, &[0, 1], 2, &mut rows).unwrap();
        assert_eq!(a, b, "tracked variant must compute the same state");
        assert_eq!(sw_plain, sw_tracked);
        assert_eq!(rows.iter().sum::<u64>(), sw_tracked, "per-row counts must sum to the total");
        // Every attributed flip is at most one per row per gate.
        assert!(rows.iter().all(|&r| r <= 1));
    }

    #[test]
    fn tracked_init_matches_untracked() {
        let mut a = BitMatrix::new(70, 3);
        a.fill_random(5);
        let mut b = a.clone();
        let sw_plain = a.init_columns(&[0, 2], true).unwrap();
        let mut rows = vec![0u64; 70];
        let sw_tracked = b.init_columns_tracked(&[0, 2], true, &mut rows).unwrap();
        assert_eq!(a, b);
        assert_eq!(sw_plain, sw_tracked);
        assert_eq!(rows.iter().sum::<u64>(), sw_tracked);
    }

    #[test]
    fn tracked_rejects_short_accumulator() {
        let mut m = BitMatrix::new(70, 3);
        let mut short = vec![0u64; 69];
        assert!(m.apply_gate_tracked(GateType::Not, &[0], 1, &mut short).is_err());
        assert!(m.init_columns_tracked(&[0], true, &mut short).is_err());
    }

    #[test]
    fn clear_rows_zeroes_exactly_the_range() {
        let mut m = BitMatrix::new(130, 5); // spans word boundaries
        m.fill_random(21);
        let before = m.clone();
        m.clear_rows(3, 70).unwrap();
        for c in 0..5 {
            for r in 0..130 {
                if (3..70).contains(&r) {
                    assert!(!m.get(r, c), "row {r} col {c} must be cleared");
                } else {
                    assert_eq!(m.get(r, c), before.get(r, c), "row {r} col {c} must be untouched");
                }
            }
        }
        // Full clear and empty clear are valid; out-of-range is rejected.
        m.clear_rows(0, 130).unwrap();
        assert_eq!(m, BitMatrix::new(130, 5));
        m.clear_rows(7, 7).unwrap();
        assert!(m.clear_rows(0, 131).is_err());
        assert!(m.clear_rows(9, 8).is_err());
    }

    /// The word-range split/merge primitive is lossless, including across a
    /// ragged tail word, and rejects malformed ranges.
    #[test]
    fn word_range_extract_splice_roundtrip() {
        let mut m = BitMatrix::new(130, 5); // 3 words per column, 2-bit tail
        m.fill_random(9);
        assert_eq!(m.words_per_col(), 3);
        let a = m.extract_word_range(0, 1).unwrap();
        let b = m.extract_word_range(1, 3).unwrap();
        assert_eq!(a.rows(), 64);
        assert_eq!(b.rows(), 66);
        for c in 0..5 {
            for r in 0..130 {
                let v = m.get(r, c);
                if r < 64 {
                    assert_eq!(a.get(r, c), v, "row {r} col {c}");
                } else {
                    assert_eq!(b.get(r - 64, c), v, "row {r} col {c}");
                }
            }
        }
        let mut back = BitMatrix::new(130, 5);
        back.splice_word_range(0, &a).unwrap();
        back.splice_word_range(1, &b).unwrap();
        assert_eq!(back, m);
        assert!(m.extract_word_range(1, 1).is_err());
        assert!(m.extract_word_range(2, 4).is_err());
        assert!(back.splice_word_range(2, &b).is_err(), "chunk overruns the column");
        assert!(back.splice_word_range(2, &a).is_err(), "tail word must come from the tail");
    }

    #[test]
    fn field_roundtrip() {
        let mut m = BitMatrix::new(8, 80);
        m.write_field(3, 10, 32, 0xdeadbeef).unwrap();
        assert_eq!(m.read_field(3, 10, 32).unwrap(), 0xdeadbeef);
        m.write_strided(5, 2, 5, 16, 0xabcd).unwrap();
        assert_eq!(m.read_strided(5, 2, 5, 16).unwrap(), 0xabcd);
    }

    #[test]
    fn f32_roundtrip() {
        let mut m = BitMatrix::new(66, 12);
        m.fill_random(7);
        let dense = m.to_f32_row_major();
        let back = BitMatrix::from_f32_row_major(66, 12, &dense).unwrap();
        assert_eq!(m, back);
    }
}
