//! The cycle-accurate crossbar: state + metrics + the two execution paths
//! (direct abstract operations, and full message decode through the
//! periphery — the production path the coordinator uses).

use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::encode::{self, BitVec};
use crate::isa::models::ModelKind;
use crate::isa::operation::Operation;
use crate::periphery;
use anyhow::Result;

/// Architectural counters accumulated by a crossbar.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Metrics {
    /// Total simulated cycles (gate cycles + init cycles).
    pub cycles: u64,
    /// Stateful-logic cycles.
    pub gate_cycles: u64,
    /// Initialization (write) cycles.
    pub init_cycles: u64,
    /// Total gates executed (the paper's energy proxy, Section 5.4: energy
    /// "is approximated by the total gate count" [18]).
    pub gate_events: u64,
    /// Memristor switching events (bit flips) — the physical energy driver.
    pub switch_events: u64,
    /// Control-message traffic received, in bits.
    pub control_bits: u64,
    /// Control messages received.
    pub messages: u64,
}

impl Metrics {
    pub fn add(&mut self, other: &Metrics) {
        self.cycles += other.cycles;
        self.gate_cycles += other.gate_cycles;
        self.init_cycles += other.init_cycles;
        self.gate_events += other.gate_events;
        self.switch_events += other.switch_events;
        self.control_bits += other.control_bits;
        self.messages += other.messages;
    }
}

/// Control traffic charged per initialization write (a plain write command,
/// outside the paper's gate-operation formats — see DESIGN.md): one
/// baseline-style `3·log2(n)`-bit message.
pub fn init_message_bits(geom: &Geometry) -> usize {
    3 * geom.log2_n()
}

/// A partitioned memristive crossbar.
#[derive(Debug, Clone)]
pub struct Crossbar {
    pub geom: Geometry,
    pub gate_set: GateSet,
    pub state: BitMatrix,
    pub metrics: Metrics,
}

impl Crossbar {
    pub fn new(geom: Geometry, gate_set: GateSet) -> Self {
        let state = BitMatrix::new(geom.rows, geom.n);
        Self { geom, gate_set, state, metrics: Metrics::default() }
    }

    /// The paper's headline configuration (n=1024, k=32).
    pub fn paper(rows: usize) -> Self {
        Self::new(Geometry::paper(rows), GateSet::NotNor)
    }

    /// Execute one abstract operation (one simulated cycle), validating the
    /// physical constraints (column ranges, section disjointness, gate set)
    /// but **not** any model's control restrictions — that is the
    /// controller's job (see [`Crossbar::execute_message`]).
    pub fn execute(&mut self, op: &Operation) -> Result<()> {
        op.validate(&self.geom, self.gate_set)?;
        self.execute_trusted(op)
    }

    /// Execute a cycle that is already known valid — the message path uses
    /// this after periphery reconstruction (which guarantees disjoint
    /// sections and alias-free NOT/NOR gates by construction), avoiding a
    /// second validation pass per message (see EXPERIMENTS.md §Perf).
    fn execute_trusted(&mut self, op: &Operation) -> Result<()> {
        match op {
            Operation::Init { cols, value } => {
                let sw = self.state.init_columns(cols, *value)?;
                self.metrics.cycles += 1;
                self.metrics.init_cycles += 1;
                self.metrics.switch_events += sw;
            }
            Operation::Gates(gates) => {
                for g in gates {
                    let sw = self.state.apply_gate(g.gate, &g.ins, g.out)?;
                    self.metrics.switch_events += sw;
                }
                self.metrics.cycles += 1;
                self.metrics.gate_cycles += 1;
                self.metrics.gate_events += gates.len() as u64;
            }
        }
        Ok(())
    }

    /// Execute a sequence of operations.
    pub fn execute_all(&mut self, ops: &[Operation]) -> Result<()> {
        for op in ops {
            self.execute(op)?;
        }
        Ok(())
    }

    /// The production path: receive a wire-format control message, decode it
    /// through the periphery of `model`, and execute the reconstructed
    /// gates. Control traffic is metered here.
    pub fn execute_message(&mut self, model: ModelKind, bits: &BitVec) -> Result<()> {
        let msg = encode::decode(model, bits, &self.geom)?;
        let op = periphery::reconstruct(&msg, &self.geom)?;
        self.metrics.control_bits += bits.len() as u64;
        self.metrics.messages += 1;
        self.execute_trusted(&op)
    }

    /// The production path for initialization writes (charged
    /// [`init_message_bits`] of control traffic).
    pub fn execute_init(&mut self, cols: &[usize], value: bool) -> Result<()> {
        self.metrics.control_bits += init_message_bits(&self.geom) as u64;
        self.metrics.messages += 1;
        self.execute(&Operation::Init { cols: cols.to_vec(), value })
    }

    /// Reset counters (state is preserved).
    pub fn reset_metrics(&mut self) {
        self.metrics = Metrics::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::operation::GateOp;

    #[test]
    fn execute_counts_cycles_and_gates() {
        let mut xb = Crossbar::new(Geometry::new(256, 8, 64).unwrap(), GateSet::NotNor);
        xb.execute(&Operation::init1(vec![2])).unwrap();
        xb.execute(&Operation::Gates(vec![GateOp::nor(0, 1, 2), GateOp::nor(32, 33, 34)])).unwrap();
        assert_eq!(xb.metrics.cycles, 2);
        assert_eq!(xb.metrics.init_cycles, 1);
        assert_eq!(xb.metrics.gate_cycles, 1);
        assert_eq!(xb.metrics.gate_events, 2);
    }

    #[test]
    fn message_path_equals_direct_path() {
        let geom = Geometry::new(256, 8, 64).unwrap();
        let op = Operation::Gates((0..8).map(|p| GateOp::nor(p * 32, p * 32 + 1, p * 32 + 3)).collect());

        let mut direct = Crossbar::new(geom, GateSet::NotNor);
        direct.state.fill_random(99);
        let wired = direct.clone();

        direct.execute(&op).unwrap();
        for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
            let mut xb = wired.clone();
            let bits = encode::encode(model, &op, &geom).unwrap();
            xb.execute_message(model, &bits).unwrap();
            assert_eq!(xb.state, direct.state, "state diverged via {} message path", model.name());
            assert_eq!(xb.metrics.control_bits, bits.len() as u64);
        }
    }

    #[test]
    fn model_restrictions_enforced_at_decode() {
        // A physically valid op that the standard codec cannot express
        // (split input) must fail at encode time, not corrupt the crossbar.
        let geom = Geometry::new(256, 8, 64).unwrap();
        let op = Operation::serial(GateOp::nor(0, 40, 80)); // inputs in p0, p1
        assert!(encode::encode(ModelKind::Standard, &op, &geom).is_err());
        assert!(encode::encode(ModelKind::Unlimited, &op, &geom).is_ok());
    }
}
