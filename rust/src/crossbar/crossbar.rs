//! The cycle-accurate bit-packed crossbar: state plus architectural
//! counters. Execution happens exclusively through the
//! [`crate::backend::PimBackend`] implementation at the bottom of this file;
//! the control paths (wire encode/decode, legalization) live in
//! [`crate::backend::pipeline`].

use crate::backend::PimBackend;
use crate::crossbar::faults::FaultMap;
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::crossbar::wear::WearMap;
use crate::isa::operation::Operation;
use anyhow::Result;

/// Architectural counters accumulated by a backend / pipeline.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Metrics {
    /// Total simulated cycles (gate cycles + init cycles).
    pub cycles: u64,
    /// Stateful-logic cycles.
    pub gate_cycles: u64,
    /// Initialization (write) cycles.
    pub init_cycles: u64,
    /// Total gates executed (the paper's energy proxy, Section 5.4: energy
    /// "is approximated by the total gate count" [18]).
    pub gate_events: u64,
    /// Memristor switching events (bit flips) — the physical energy driver.
    pub switch_events: u64,
    /// Control-message traffic received, in bits (metered at the pipeline's
    /// periphery-decode boundary).
    pub control_bits: u64,
    /// Control messages received.
    pub messages: u64,
}

impl Metrics {
    pub fn add(&mut self, other: &Metrics) {
        self.cycles += other.cycles;
        self.gate_cycles += other.gate_cycles;
        self.init_cycles += other.init_cycles;
        self.gate_events += other.gate_events;
        self.switch_events += other.switch_events;
        self.control_bits += other.control_bits;
        self.messages += other.messages;
    }

    /// Field-wise difference against an earlier snapshot (for per-batch
    /// accounting). Saturates instead of panicking on counter resets.
    pub fn delta_since(&self, before: &Metrics) -> Metrics {
        Metrics {
            cycles: self.cycles.saturating_sub(before.cycles),
            gate_cycles: self.gate_cycles.saturating_sub(before.gate_cycles),
            init_cycles: self.init_cycles.saturating_sub(before.init_cycles),
            gate_events: self.gate_events.saturating_sub(before.gate_events),
            switch_events: self.switch_events.saturating_sub(before.switch_events),
            control_bits: self.control_bits.saturating_sub(before.control_bits),
            messages: self.messages.saturating_sub(before.messages),
        }
    }
}

/// Control traffic charged per initialization write (a plain write command,
/// outside the paper's gate-operation formats — see DESIGN.md): one
/// baseline-style `3·log2(n)`-bit message.
pub fn init_message_bits(geom: &Geometry) -> usize {
    3 * geom.log2_n()
}

/// Run a trusted operation stream over one word-range chunk of the state.
/// Stateful logic never crosses rows, so every chunk executes the full
/// stream independently; the caller merges the chunks back and sums the
/// switching events. Returns the chunk's switch total plus (when `track` is
/// set) its local per-row switch accumulator, indexed from the chunk's own
/// row 0.
fn run_trusted_ops(m: &mut BitMatrix, ops: &[Operation], track: bool) -> Result<(u64, Vec<u64>)> {
    let mut acc = if track { vec![0u64; m.rows()] } else { Vec::new() };
    let mut switches = 0u64;
    for op in ops {
        match op {
            Operation::Init { cols, value } => {
                switches += if track {
                    m.init_columns_tracked(cols, *value, &mut acc)?
                } else {
                    m.init_columns(cols, *value)?
                };
            }
            Operation::Gates(gates) => {
                for g in gates {
                    switches += if track {
                        m.apply_gate_tracked(g.gate, &g.ins, g.out, &mut acc)?
                    } else {
                        m.apply_gate(g.gate, &g.ins, g.out)?
                    };
                }
            }
        }
    }
    Ok((switches, acc))
}

/// A partitioned memristive crossbar (the bit-packed production backend).
#[derive(Debug, Clone)]
pub struct Crossbar {
    pub geom: Geometry,
    pub gate_set: GateSet,
    pub state: BitMatrix,
    pub metrics: Metrics,
    /// Per-row switch-event counters, enabled by
    /// [`Crossbar::enable_row_switch_tracking`]. The coordinator uses them
    /// to charge each segment of a coalesced row-batch its exact row-range
    /// switching energy; `None` (the default) keeps the simulator hot path
    /// free of per-bit attribution work.
    row_switches: Option<Vec<u64>>,
    /// Stuck-at cells of this physical array. Applied on the serving path
    /// via [`Crossbar::apply_faults`]; empty by default.
    faults: FaultMap,
    /// Persistent per-row wear: the exact switch attribution folded in by
    /// [`Crossbar::absorb_wear`] across batches. Survives `clear_rows` —
    /// wear is physical, not logical.
    wear: WearMap,
}

impl Crossbar {
    pub fn new(geom: Geometry, gate_set: GateSet) -> Self {
        let state = BitMatrix::new(geom.rows, geom.n);
        Self {
            geom,
            gate_set,
            state,
            metrics: Metrics::default(),
            row_switches: None,
            faults: FaultMap::new(),
            wear: WearMap::new(geom.rows),
        }
    }

    /// The paper's headline configuration (n=1024, k=32), routed through the
    /// validating [`Geometry::new`] like every other construction.
    pub fn paper(rows: usize) -> Result<Self> {
        Ok(Self::new(Geometry::paper(rows)?, GateSet::NotNor))
    }

    /// Start attributing every switching event to its row (counters reset to
    /// zero). Costs one bit-scan per flipped word on the gate path.
    pub fn enable_row_switch_tracking(&mut self) {
        self.row_switches = Some(vec![0; self.geom.rows]);
    }

    /// Zero the per-row switch counters (start of a batch). No-op while
    /// tracking is disabled.
    pub fn reset_row_switches(&mut self) {
        if let Some(acc) = &mut self.row_switches {
            acc.iter_mut().for_each(|x| *x = 0);
        }
    }

    /// Switch events attributed to rows `start..end` since the last reset.
    /// Returns 0 while tracking is disabled.
    pub fn row_switches(&self, start: usize, end: usize) -> u64 {
        match &self.row_switches {
            Some(acc) => acc[start.min(acc.len())..end.min(acc.len())].iter().sum(),
            None => 0,
        }
    }

    /// Switch events attributed to exactly the given rows since the last
    /// reset — the scattered-placement counterpart of
    /// [`Crossbar::row_switches`]. Returns 0 while tracking is disabled.
    pub fn row_switches_at(&self, rows: &[usize]) -> u64 {
        match &self.row_switches {
            Some(acc) => rows.iter().filter_map(|&r| acc.get(r)).sum(),
            None => 0,
        }
    }

    /// A copy of the per-row switch counters since the last reset (empty
    /// while tracking is disabled).
    pub fn row_switches_snapshot(&self) -> Vec<u64> {
        self.row_switches.clone().unwrap_or_default()
    }

    /// Replace this array's stuck-at fault map.
    pub fn set_faults(&mut self, faults: FaultMap) {
        self.faults = faults;
    }

    /// Force every stuck cell to its stuck value. The serving path calls
    /// this after operand loads (faults corrupt inputs) and after replay
    /// (faults corrupt outputs); it writes through `BitMatrix::set`, so it
    /// never perturbs the switch-event metrics. Errors only on a fault
    /// outside the array bounds.
    pub fn apply_faults(&mut self) -> Result<()> {
        if self.faults.faults.is_empty() {
            return Ok(());
        }
        self.faults.apply(&mut self.state)
    }

    /// Rows containing at least one stuck cell, ascending and deduplicated —
    /// the dispatcher's quarantine probe.
    pub fn stuck_rows(&self) -> Vec<usize> {
        let mut rows: Vec<usize> = self.faults.faults.iter().map(|f| f.row).collect();
        rows.sort_unstable();
        rows.dedup();
        rows
    }

    /// The persistent per-row wear map of this physical array.
    pub fn wear(&self) -> &WearMap {
        &self.wear
    }

    /// Fold the current per-row switch counters into the persistent wear map
    /// and return the snapshot that was absorbed (so callers can attribute
    /// the same batch's wear elsewhere). Call once per batch, after replay
    /// and before the next reset.
    pub fn absorb_wear(&mut self) -> Vec<u64> {
        let snapshot = self.row_switches_snapshot();
        self.wear.absorb(&snapshot);
        snapshot
    }

    /// Apply one already-validated cycle and account for it. Shared by the
    /// validating and trusted trait paths.
    fn step_trusted(&mut self, op: &Operation) -> Result<()> {
        match op {
            Operation::Init { cols, value } => {
                let sw = match self.row_switches.as_deref_mut() {
                    Some(acc) => self.state.init_columns_tracked(cols, *value, acc)?,
                    None => self.state.init_columns(cols, *value)?,
                };
                self.metrics.cycles += 1;
                self.metrics.init_cycles += 1;
                self.metrics.switch_events += sw;
            }
            Operation::Gates(gates) => {
                for g in gates {
                    let sw = match self.row_switches.as_deref_mut() {
                        Some(acc) => self.state.apply_gate_tracked(g.gate, &g.ins, g.out, acc)?,
                        None => self.state.apply_gate(g.gate, &g.ins, g.out)?,
                    };
                    self.metrics.switch_events += sw;
                }
                self.metrics.cycles += 1;
                self.metrics.gate_cycles += 1;
                self.metrics.gate_events += gates.len() as u64;
            }
        }
        Ok(())
    }
}

impl PimBackend for Crossbar {
    fn name(&self) -> &'static str {
        "bit-packed"
    }

    fn geom(&self) -> Geometry {
        self.geom
    }

    fn gate_set(&self) -> GateSet {
        self.gate_set
    }

    fn load_state(&mut self, m: &BitMatrix) -> Result<()> {
        crate::backend::check_state_shape(&self.geom, m)?;
        self.state = m.clone();
        Ok(())
    }

    fn state_bits(&self) -> Result<BitMatrix> {
        Ok(self.state.clone())
    }

    fn execute(&mut self, op: &Operation) -> Result<()> {
        op.validate(&self.geom, self.gate_set)?;
        self.step_trusted(op)
    }

    /// The periphery decode stage reconstructs only physically valid
    /// operations, so the message path skips the second validation pass
    /// (see DESIGN.md §Perf).
    fn execute_trusted(&mut self, op: &Operation) -> Result<()> {
        self.step_trusted(op)
    }

    /// Word-range-parallel batch execution (DESIGN.md §Replay fast path):
    /// rows never interact in stateful logic, so the column-major 64-bit
    /// words split into up to `threads` contiguous ranges that each execute
    /// the whole trusted stream independently under scoped threads. Switch
    /// events sum across ranges and the per-row tracked counters land in
    /// disjoint row windows, so the merged metrics are bit-identical to the
    /// serial path. A batch carrying a malformed write command is rejected
    /// before any cell or counter changes, in every thread configuration.
    fn execute_trusted_batch(&mut self, ops: &[Operation], threads: usize) -> Result<()> {
        // Write commands sit outside the periphery reconstruction guarantee:
        // validate them all up front, identically in the serial and the
        // parallel path.
        for op in ops {
            if matches!(op, Operation::Init { .. }) {
                op.validate(&self.geom, self.gate_set)?;
            }
        }
        let wpc = self.state.words_per_col();
        let t = threads.clamp(1, wpc);
        if t == 1 || ops.is_empty() {
            for op in ops {
                self.step_trusted(op)?;
            }
            return Ok(());
        }
        let track = self.row_switches.is_some();
        let mut ranges = Vec::with_capacity(t);
        let (base, extra) = (wpc / t, wpc % t);
        let mut w0 = 0;
        for i in 0..t {
            let w1 = w0 + base + usize::from(i < extra);
            ranges.push((w0, w1));
            w0 = w1;
        }
        let mut chunks =
            ranges.iter().map(|&(a, b)| self.state.extract_word_range(a, b)).collect::<Result<Vec<_>>>()?;
        let results: Vec<Result<(u64, Vec<u64>)>> = std::thread::scope(|s| {
            let handles: Vec<_> =
                chunks.iter_mut().map(|chunk| s.spawn(move || run_trusted_ops(chunk, ops, track))).collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow::anyhow!("word-range executor thread panicked"))))
                .collect()
        });
        // All-or-nothing merge: splice and charge only once every range
        // executed cleanly, so a failed batch leaves the crossbar untouched.
        let mut outcomes = Vec::with_capacity(t);
        for r in results {
            outcomes.push(r?);
        }
        for ((&(a, _), chunk), (switches, acc)) in ranges.iter().zip(&chunks).zip(&outcomes) {
            self.state.splice_word_range(a, chunk)?;
            self.metrics.switch_events += switches;
            if let Some(dst) = &mut self.row_switches {
                for (i, v) in acc.iter().enumerate() {
                    dst[a * 64 + i] += v;
                }
            }
        }
        for op in ops {
            match op {
                Operation::Init { .. } => {
                    self.metrics.cycles += 1;
                    self.metrics.init_cycles += 1;
                }
                Operation::Gates(gates) => {
                    self.metrics.cycles += 1;
                    self.metrics.gate_cycles += 1;
                    self.metrics.gate_events += gates.len() as u64;
                }
            }
        }
        Ok(())
    }

    fn metrics(&self) -> Metrics {
        self.metrics
    }

    fn reset_metrics(&mut self) {
        self.metrics = Metrics::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::ExecPipeline;
    use crate::isa::encode;
    use crate::isa::models::ModelKind;
    use crate::isa::operation::GateOp;

    #[test]
    fn execute_counts_cycles_and_gates() {
        let mut xb = Crossbar::new(Geometry::new(256, 8, 64).unwrap(), GateSet::NotNor);
        xb.execute(&Operation::init1(vec![2])).unwrap();
        xb.execute(&Operation::Gates(vec![GateOp::nor(0, 1, 2), GateOp::nor(32, 33, 34)])).unwrap();
        assert_eq!(xb.metrics.cycles, 2);
        assert_eq!(xb.metrics.init_cycles, 1);
        assert_eq!(xb.metrics.gate_cycles, 1);
        assert_eq!(xb.metrics.gate_events, 2);
    }

    /// Row tracking is a pure observer: same state, same totals, and the
    /// per-row counters partition the total switch count exactly.
    #[test]
    fn row_switch_tracking_partitions_the_total() {
        let geom = Geometry::new(256, 8, 64).unwrap();
        let ops = vec![
            Operation::init1(vec![2, 40]),
            Operation::Gates(vec![GateOp::nor(0, 1, 2), GateOp::nor(32, 33, 34)]),
            Operation::Gates(vec![GateOp::nor(2, 34, 70)]),
        ];
        let mut plain = Crossbar::new(geom, GateSet::NotNor);
        plain.state.fill_random(17);
        let mut tracked = plain.clone();
        tracked.enable_row_switch_tracking();
        for op in &ops {
            plain.execute(op).unwrap();
            tracked.execute(op).unwrap();
        }
        assert_eq!(plain.state, tracked.state);
        assert_eq!(plain.metrics, tracked.metrics);
        assert_eq!(tracked.row_switches(0, 64), tracked.metrics.switch_events);
        assert_eq!(
            tracked.row_switches(0, 10) + tracked.row_switches(10, 64),
            tracked.metrics.switch_events,
            "row ranges partition the total"
        );
        tracked.reset_row_switches();
        assert_eq!(tracked.row_switches(0, 64), 0);
    }

    #[test]
    fn message_path_equals_direct_path() {
        let geom = Geometry::new(256, 8, 64).unwrap();
        let op = Operation::Gates((0..8).map(|p| GateOp::nor(p * 32, p * 32 + 1, p * 32 + 3)).collect());

        let mut direct = Crossbar::new(geom, GateSet::NotNor);
        direct.state.fill_random(99);
        let wired = direct.clone();

        direct.execute(&op).unwrap();
        for model in [ModelKind::Unlimited, ModelKind::Standard, ModelKind::Minimal] {
            let mut xb = wired.clone();
            let bits = encode::encode(model, &op, &geom).unwrap();
            let mut pipe = ExecPipeline::wire(model, &mut xb);
            pipe.run_op(&op).unwrap();
            assert_eq!(pipe.metrics().control_bits, bits.len() as u64);
            drop(pipe);
            assert_eq!(xb.state, direct.state, "state diverged via {} message path", model.name());
        }
    }

    #[test]
    fn model_restrictions_enforced_at_encode() {
        // A physically valid op that the standard codec cannot express
        // (split input) must fail at encode time, not corrupt the crossbar.
        let geom = Geometry::new(256, 8, 64).unwrap();
        let op = Operation::serial(GateOp::nor(0, 40, 80)); // inputs in p0, p1
        assert!(encode::encode(ModelKind::Standard, &op, &geom).is_err());
        assert!(encode::encode(ModelKind::Unlimited, &op, &geom).is_ok());
        // And the wire pipeline surfaces the same error without executing.
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        let mut pipe = ExecPipeline::wire(ModelKind::Standard, &mut xb);
        assert!(pipe.run_op(&op).is_err());
        assert_eq!(pipe.metrics().cycles, 0);
    }

    /// Word-range-parallel batch execution is bitwise- and metric-identical
    /// to the serial trusted path, including per-row switch attribution
    /// across word boundaries and a ragged tail word.
    #[test]
    fn trusted_batch_parallel_matches_serial() {
        let geom = Geometry::new(256, 8, 200).unwrap(); // 4 words per column, 8-bit tail
        let ops = vec![
            Operation::init1(vec![2, 40, 70]),
            Operation::Gates(vec![GateOp::nor(0, 1, 2), GateOp::nor(32, 33, 34)]),
            Operation::Gates(vec![GateOp::nor(2, 34, 70)]),
            Operation::Init { cols: vec![100], value: false },
            Operation::Gates(vec![GateOp::not(70, 100)]),
        ];
        let mut serial = Crossbar::new(geom, GateSet::NotNor);
        serial.state.fill_random(31);
        serial.enable_row_switch_tracking();
        let mut par = serial.clone();
        let mut wide = serial.clone();
        for op in &ops {
            serial.execute_trusted(op).unwrap();
        }
        par.execute_trusted_batch(&ops, 3).unwrap();
        assert_eq!(par.state, serial.state);
        assert_eq!(par.metrics, serial.metrics);
        for r in 0..200 {
            assert_eq!(par.row_switches(r, r + 1), serial.row_switches(r, r + 1), "row {r} attribution");
        }
        // More threads than words per column clamps instead of failing.
        wide.execute_trusted_batch(&ops, 64).unwrap();
        assert_eq!(wide.state, serial.state);
        assert_eq!(wide.metrics, serial.metrics);
    }

    /// A batch carrying a malformed write command is rejected before any
    /// cell or counter changes, in every thread configuration.
    #[test]
    fn trusted_batch_rejects_malformed_write_untouched() {
        let geom = Geometry::new(256, 8, 200).unwrap();
        let mut xb = Crossbar::new(geom, GateSet::NotNor);
        xb.state.fill_random(3);
        let before = xb.state.clone();
        let ops =
            vec![Operation::Gates(vec![GateOp::nor(0, 1, 2)]), Operation::Init { cols: vec![geom.n + 1], value: true }];
        assert!(xb.execute_trusted_batch(&ops, 2).is_err());
        assert!(xb.execute_trusted_batch(&ops, 1).is_err());
        assert_eq!(xb.state, before, "a rejected batch must not touch any cell");
        assert_eq!(xb.metrics, Metrics::default());
    }

    #[test]
    fn metrics_delta() {
        let a = Metrics { cycles: 10, gate_events: 7, ..Default::default() };
        let b = Metrics { cycles: 25, gate_events: 9, control_bits: 36, ..a };
        let d = b.delta_since(&a);
        assert_eq!(d.cycles, 15);
        assert_eq!(d.gate_events, 2);
        assert_eq!(d.control_bits, 36);
    }
}
