//! Cycle-accurate memristive crossbar simulator.
//!
//! The crossbar stores one bit per memristor in an `rows × n` array. Stateful
//! logic executes *column* gates: applying voltages on a handful of bitlines
//! computes, e.g., `out[r] = NOR(a[r], b[r])` **in every row simultaneously**
//! in a single cycle (Figure 1 of the paper). Partitions insert `k-1`
//! isolation transistors per row so that several column gates can execute
//! concurrently in disjoint *sections* of the same row (Figure 2).
//!
//! The simulator is bit-packed column-major: each column is a `rows/64`-word
//! bitvector, so a row-parallel gate is a handful of word-wide boolean ops —
//! this is the L3 hot path (see `benches/sim_throughput.rs`).

pub mod crossbar;
pub mod faults;
pub mod gate;
pub mod geometry;
pub mod state;

pub use crossbar::{Crossbar, Metrics};
pub use gate::{GateSet, GateType};
pub use geometry::Geometry;
pub use state::BitMatrix;
