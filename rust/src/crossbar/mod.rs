//! Cycle-accurate memristive crossbar simulator.
//!
//! The crossbar stores one bit per memristor in an `rows × n` array. Stateful
//! logic executes *column* gates: applying voltages on a handful of bitlines
//! computes, e.g., `out[r] = NOR(a[r], b[r])` **in every row simultaneously**
//! in a single cycle (Figure 1 of the paper). Partitions insert `k-1`
//! isolation transistors per row so that several column gates can execute
//! concurrently in disjoint *sections* of the same row (Figure 2).
//!
//! The simulator is bit-packed column-major: each column is a `rows/64`-word
//! bitvector, so a row-parallel gate is a handful of word-wide boolean ops —
//! this is the L3 hot path (see `benches/sim_throughput.rs`).
//!
//! Device reliability rides on two side structures: [`faults::FaultMap`]
//! injects stuck-at cells (applied through the serving path after loads and
//! replays), and [`wear::WearMap`] persistently accumulates the exact per-row
//! switch attribution across batches — wear is physical, so it survives row
//! clearing — and carries the quarantine ledger plus wear-leveling placement
//! used by the coordinator (DESIGN.md §Wear).

pub mod crossbar;
pub mod faults;
pub mod gate;
pub mod geometry;
pub mod state;
pub mod wear;

pub use crossbar::{Crossbar, Metrics};
pub use faults::{FaultMap, StuckAt};
pub use gate::{GateSet, GateType};
pub use geometry::Geometry;
pub use state::BitMatrix;
pub use wear::{WearMap, WearSummary};
