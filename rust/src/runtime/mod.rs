//! PJRT/XLA execution of the AOT-compiled JAX/Pallas crossbar step.
//!
//! The build-time python stack (`python/compile/`) lowers the Pallas
//! gate-step kernel — one simulated stateful-logic cycle over the whole
//! crossbar, formulated as MXU matmuls over one-hot column selectors — to
//! HLO **text** (`artifacts/step_*.hlo.txt`). This module loads those
//! artifacts with the `xla` crate's PJRT CPU client and exposes them as an
//! alternative crossbar backend, used to cross-check the bit-packed rust
//! simulator (experiment E14). Python never runs at request time.

pub mod backend;
pub mod stepper;

pub use backend::XlaCrossbar;
pub use stepper::{artifact_path, ops_to_steps, GateSlot, XlaStepper};
