//! PJRT/XLA execution of the AOT-compiled JAX/Pallas crossbar step.
//!
//! The build-time python stack (`python/compile/`) lowers the Pallas
//! gate-step kernel — one simulated stateful-logic cycle over the whole
//! crossbar, formulated as MXU matmuls over one-hot column selectors — to
//! HLO **text** (`artifacts/step_*.hlo.txt`). This module loads those
//! artifacts with the `xla` crate's PJRT CPU client and exposes them as an
//! alternative [`crate::backend::PimBackend`], used to cross-check the
//! bit-packed rust simulator (experiment E14). Python never runs at request
//! time.
//!
//! The `xla` crate is not part of the offline vendor set, so the real
//! backend compiles only behind the `xla` cargo feature (see DESIGN.md
//! §Substitutions). Without it, [`XlaCrossbar::new`] returns an error and
//! everything else (including the operation→step lowering in [`steps`],
//! which has no XLA dependency) still builds and tests.

pub mod backend;
pub mod steps;
#[cfg(feature = "xla")]
pub mod stepper;

pub use backend::XlaCrossbar;
pub use steps::{artifact_path, ops_to_steps, GateSlot};
#[cfg(feature = "xla")]
pub use stepper::XlaStepper;
