//! Lowering abstract operations to the gate-step artifact's input layout.
//! This is pure data transformation with no XLA dependency, so it compiles
//! (and is unit-tested) with or without the `xla` feature.

use crate::isa::operation::Operation;
use anyhow::{ensure, Result};
use std::path::{Path, PathBuf};

/// One gate slot of a step: `(in_a, in_b, out, mode)` with `-1` marking an
/// unused index and `mode = 1` turning the slot into a write-0
/// (initialization to 1 is `NOR(0, 0)` with both inputs unused).
pub type GateSlot = [i32; 4];

/// Path of the step artifact for a given shape.
pub fn artifact_path(dir: &Path, rows: usize, cols: usize, gates: usize) -> PathBuf {
    dir.join(format!("step_r{rows}_c{cols}_g{gates}.hlo.txt"))
}

/// Convert a program's operations into padded step descriptors for the
/// artifact's fixed `gates` width. Gate cycles map 1:1; initialization
/// writes expand into `ceil(columns / gates)` steps of write slots.
pub fn ops_to_steps(ops: &[Operation], gates: usize) -> Result<Vec<Vec<GateSlot>>> {
    let mut steps = Vec::new();
    for op in ops {
        match op {
            Operation::Gates(gs) => {
                ensure!(gs.len() <= gates, "operation has {} gates, artifact supports {gates}", gs.len());
                let mut step: Vec<GateSlot> = gs
                    .iter()
                    .map(|g| {
                        let a = g.ins[0] as i32;
                        let b = *g.ins.get(1).unwrap_or(&g.ins[0]) as i32;
                        [a, b, g.out as i32, 0]
                    })
                    .collect();
                step.resize(gates, [-1, -1, -1, 0]);
                steps.push(step);
            }
            Operation::Init { cols, value } => {
                let mode = if *value { 0 } else { 1 };
                // Deduplicate: the one-hot output scatter must see each
                // column at most once per step (writing twice is idempotent
                // for an init anyway).
                let mut cols = cols.clone();
                cols.sort_unstable();
                cols.dedup();
                for chunk in cols.chunks(gates) {
                    let mut step: Vec<GateSlot> = chunk.iter().map(|&c| [-1, -1, c as i32, mode]).collect();
                    step.resize(gates, [-1, -1, -1, 0]);
                    steps.push(step);
                }
            }
        }
    }
    Ok(steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::operation::GateOp;

    #[test]
    fn gate_cycles_map_one_to_one() {
        let op = Operation::Gates(vec![GateOp::nor(0, 1, 2), GateOp::not(8, 9)]);
        let steps = ops_to_steps(std::slice::from_ref(&op), 4).unwrap();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0], vec![[0, 1, 2, 0], [8, 8, 9, 0], [-1, -1, -1, 0], [-1, -1, -1, 0]]);
    }

    #[test]
    fn wide_inits_chunk_and_dedup() {
        let op = Operation::Init { cols: vec![5, 1, 5, 3], value: false };
        let steps = ops_to_steps(std::slice::from_ref(&op), 2).unwrap();
        assert_eq!(steps, vec![vec![[-1, -1, 1, 1], [-1, -1, 3, 1]], vec![[-1, -1, 5, 1], [-1, -1, -1, 0]]]);
    }

    #[test]
    fn oversized_cycle_rejected() {
        let op = Operation::Gates((0..5).map(|i| GateOp::not(i * 2, i * 2 + 1)).collect());
        assert!(ops_to_steps(std::slice::from_ref(&op), 4).is_err());
    }
}
