//! Loading and invoking the AOT gate-step artifact (real PJRT client —
//! compiled only with the `xla` feature; see `runtime/mod.rs`).

use crate::crossbar::geometry::Geometry;
use crate::runtime::steps::{artifact_path, GateSlot};
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// A compiled PJRT executable for one step shape.
pub struct XlaStepper {
    exe: xla::PjRtLoadedExecutable,
    pub rows: usize,
    pub cols: usize,
    pub gates: usize,
}

impl XlaStepper {
    /// Load `step_r{rows}_c{cols}_g{gates}.hlo.txt` from `dir` and compile
    /// it on the PJRT CPU client.
    pub fn load(dir: &Path, rows: usize, cols: usize, gates: usize) -> Result<Self> {
        let path = artifact_path(dir, rows, cols, gates);
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT client: {e}"))?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .map_err(|e| anyhow::anyhow!("loading {}: {e} (run `make artifacts` first)", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
        Ok(Self { exe, rows, cols, gates })
    }

    /// Execute one simulated cycle: `state` is the dense row-major 0/1
    /// `f32` crossbar image.
    pub fn step(&self, state: &[f32], slots: &[GateSlot]) -> Result<Vec<f32>> {
        ensure!(state.len() == self.rows * self.cols, "state size mismatch");
        ensure!(slots.len() == self.gates, "expected {} gate slots, got {}", self.gates, slots.len());
        let state_lit = xla::Literal::vec1(state)
            .reshape(&[self.rows as i64, self.cols as i64])
            .map_err(|e| anyhow::anyhow!("state literal: {e}"))?;
        let flat: Vec<i32> = slots.iter().flatten().copied().collect();
        let idx_lit = xla::Literal::vec1(&flat)
            .reshape(&[self.gates as i64, 4])
            .map_err(|e| anyhow::anyhow!("idx literal: {e}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[state_lit, idx_lit])
            .map_err(|e| anyhow::anyhow!("execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e}"))?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
    }

    /// Stepper shape compatible with `geom`?
    pub fn matches(&self, geom: &Geometry) -> bool {
        self.rows == geom.rows && self.cols == geom.n
    }
}
