//! An XLA-backed [`PimBackend`]: same observable semantics as the
//! bit-packed [`crate::crossbar::Crossbar`], but every cycle executes
//! through the AOT-compiled Pallas gate-step kernel on the PJRT CPU client.
//!
//! Built without the `xla` feature, the same type exists with the same
//! surface but its constructor reports the missing backend — callers handle
//! one `Result` either way.

use crate::backend::PimBackend;
use crate::crossbar::crossbar::Metrics;
use crate::crossbar::gate::GateSet;
use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::operation::Operation;
use anyhow::Result;
use std::path::Path;

#[cfg(feature = "xla")]
mod real {
    use super::*;
    use crate::runtime::steps::ops_to_steps;
    use crate::runtime::stepper::XlaStepper;
    use anyhow::ensure;

    /// Crossbar whose state transitions run on XLA.
    pub struct XlaCrossbar {
        pub geom: Geometry,
        stepper: XlaStepper,
        /// Dense row-major 0/1 image of the crossbar.
        state: Vec<f32>,
        metrics: Metrics,
    }

    impl XlaCrossbar {
        /// Load the matching step artifact from `dir` (gate width = `k`, the
        /// maximum concurrent gates a partitioned operation can hold).
        pub fn new(geom: Geometry, dir: &Path) -> Result<Self> {
            let stepper = XlaStepper::load(dir, geom.rows, geom.n, geom.k)?;
            ensure!(stepper.matches(&geom), "artifact shape mismatch");
            Ok(Self { geom, stepper, state: vec![0.0; geom.rows * geom.n], metrics: Metrics::default() })
        }
    }

    impl PimBackend for XlaCrossbar {
        fn name(&self) -> &'static str {
            "xla-pjrt"
        }

        fn geom(&self) -> Geometry {
            self.geom
        }

        fn gate_set(&self) -> GateSet {
            // The step artifact implements the NOR/NOT (write-capable) slot
            // semantics only.
            GateSet::NotNor
        }

        fn load_state(&mut self, m: &BitMatrix) -> Result<()> {
            crate::backend::check_state_shape(&self.geom, m)?;
            self.state = m.to_f32_row_major();
            Ok(())
        }

        fn state_bits(&self) -> Result<BitMatrix> {
            BitMatrix::from_f32_row_major(self.geom.rows, self.geom.n, &self.state)
        }

        fn execute(&mut self, op: &Operation) -> Result<()> {
            op.validate(&self.geom, self.gate_set())?;
            for step in ops_to_steps(std::slice::from_ref(op), self.stepper.gates)? {
                self.state = self.stepper.step(&self.state, &step)?;
            }
            match op {
                Operation::Init { .. } => self.metrics.init_cycles += 1,
                Operation::Gates(gs) => {
                    self.metrics.gate_cycles += 1;
                    self.metrics.gate_events += gs.len() as u64;
                }
            }
            self.metrics.cycles += 1;
            Ok(())
        }

        fn metrics(&self) -> Metrics {
            // switch_events stays 0: the XLA image does not expose per-cell
            // flip counts; cross-checking energy uses the CPU backends.
            self.metrics
        }

        fn reset_metrics(&mut self) {
            self.metrics = Metrics::default();
        }
    }
}

#[cfg(feature = "xla")]
pub use real::XlaCrossbar;

#[cfg(not(feature = "xla"))]
mod stub {
    use super::*;

    /// Stub built without the `xla` feature: construction always fails with
    /// an actionable message, so code paths that *optionally* cross-check
    /// against XLA degrade gracefully.
    pub struct XlaCrossbar {
        pub geom: Geometry,
    }

    impl XlaCrossbar {
        pub fn new(_geom: Geometry, _dir: &Path) -> Result<Self> {
            anyhow::bail!(
                "the XLA/PJRT backend was compiled out: build with `--features xla` \
                 after adding the `xla` crate (see DESIGN.md §Substitutions)"
            )
        }
    }

    impl PimBackend for XlaCrossbar {
        fn name(&self) -> &'static str {
            "xla-pjrt (unavailable)"
        }

        fn geom(&self) -> Geometry {
            self.geom
        }

        fn gate_set(&self) -> GateSet {
            GateSet::NotNor
        }

        fn load_state(&mut self, _m: &BitMatrix) -> Result<()> {
            anyhow::bail!("XLA backend unavailable (built without the `xla` feature)")
        }

        fn state_bits(&self) -> Result<BitMatrix> {
            anyhow::bail!("XLA backend unavailable (built without the `xla` feature)")
        }

        fn execute(&mut self, _op: &Operation) -> Result<()> {
            anyhow::bail!("XLA backend unavailable (built without the `xla` feature)")
        }

        fn metrics(&self) -> Metrics {
            Metrics::default()
        }

        fn reset_metrics(&mut self) {}
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::XlaCrossbar;

#[cfg(all(test, not(feature = "xla")))]
mod tests {
    use super::*;

    #[test]
    fn stub_constructor_reports_missing_feature() {
        let geom = Geometry::new(256, 8, 16).unwrap();
        let err = XlaCrossbar::new(geom, Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
