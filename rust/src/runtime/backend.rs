//! An XLA-backed crossbar: same observable semantics as the bit-packed
//! [`crate::crossbar::Crossbar`], but every cycle executes through the
//! AOT-compiled Pallas gate-step kernel on the PJRT CPU client.

use crate::crossbar::geometry::Geometry;
use crate::crossbar::state::BitMatrix;
use crate::isa::operation::Operation;
use crate::runtime::stepper::{ops_to_steps, XlaStepper};
use anyhow::{ensure, Result};
use std::path::Path;

/// Crossbar whose state transitions run on XLA.
pub struct XlaCrossbar {
    pub geom: Geometry,
    stepper: XlaStepper,
    /// Dense row-major 0/1 image of the crossbar.
    state: Vec<f32>,
}

impl XlaCrossbar {
    /// Load the matching step artifact from `dir` (gate width = `k`, the
    /// maximum concurrent gates a partitioned operation can hold).
    pub fn new(geom: Geometry, dir: &Path) -> Result<Self> {
        let stepper = XlaStepper::load(dir, geom.rows, geom.n, geom.k)?;
        ensure!(stepper.matches(&geom), "artifact shape mismatch");
        Ok(Self { geom, stepper, state: vec![0.0; geom.rows * geom.n] })
    }

    /// Overwrite the state from a bit matrix.
    pub fn load_state(&mut self, m: &BitMatrix) {
        self.state = m.to_f32_row_major();
    }

    /// Snapshot the state as a bit matrix.
    pub fn state_bits(&self) -> Result<BitMatrix> {
        BitMatrix::from_f32_row_major(self.geom.rows, self.geom.n, &self.state)
    }

    /// Execute a sequence of operations through the XLA step kernel.
    pub fn execute_all(&mut self, ops: &[Operation]) -> Result<()> {
        for step in ops_to_steps(ops, self.stepper.gates)? {
            self.state = self.stepper.step(&self.state, &step)?;
        }
        Ok(())
    }
}
