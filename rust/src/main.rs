//! `repro` — the PartitionPIM command-line driver.
//!
//! Subcommands (no clap vendored in this environment; see
//! DESIGN.md §Substitutions):
//!
//! ```text
//! repro report                      control formats, lower bounds, periphery
//! repro figure6                     regenerate Figure 6 (latency/control/area)
//! repro sort                        sorting speedup table (intro claim)
//! repro sha3 [--model M] [--rows R] Keccak-f[1600] round table vs the
//!                                   published HashPIM budget + oracle check
//! repro serve [--model M] [--crossbars N] [--rows R] [--jobs J] [--len L]
//!             [--inject-bad] [--kill W] [--no-coalesce]
//!             [--wire-replay] [--replay-threads T]
//!             [--endurance-budget N] [--no-wear-level] [--inject-stuck R,C]
//!                                   end-to-end vector-multiply service demo
//!                                   (pipelined jobs, cross-job coalescing,
//!                                   decode-once replay — --wire-replay
//!                                   forces the full per-batch decode,
//!                                   --replay-threads spreads each replay
//!                                   over T word ranges; optional fault
//!                                   injection, wear-leveling ablation and
//!                                   endurance-horizon reporting)
//! repro serve --banks N [--mix mul:add:sort:sha3] [--spares S] [--max-pending P]
//!             [--kill-bank B] [...single-bank flags]
//!                                   multi-bank fleet demo: mixed traffic
//!                                   routed across N banks, admission
//!                                   control, hot-spare promotion on bank
//!                                   death
//! repro lint [--all] [--model M] [--deny-warnings]
//!                                   statically verify every built-in workload
//!                                   program against every control model
//!                                   (exits nonzero on error diagnostics)
//! repro xla-parity [--artifacts D] [--n N] [--k K] [--rows R]
//!                                   cross-check rust sim vs the XLA artifact
//! ```

use anyhow::{bail, Context, Result};
use partition_pim::algorithms::multpim::{build_multpim, MultPimVariant};
use partition_pim::algorithms::sha3;
use partition_pim::backend::{ExecPipeline, PimBackend, ReplayMode};
use partition_pim::coordinator::worker::{SORT_BITS, SORT_ELEMS};
use partition_pim::coordinator::{compile_workload, workload_geometry, FleetConfig, JobShape, PimFleet, PimService, ServiceConfig, WorkloadKind};
use partition_pim::crossbar::crossbar::Crossbar;
use partition_pim::crossbar::gate::GateSet;
use partition_pim::crossbar::geometry::Geometry;
use partition_pim::figures;
use partition_pim::isa::models::ModelKind;
use partition_pim::runtime::XlaCrossbar;
use partition_pim::verify::{self, Severity};
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            // A flag followed by another flag (or nothing) is boolean.
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(key.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    flags.insert(key.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    flags
}

/// Parse `--inject-stuck R,C` or `R,C,V` (stuck value `V` in `{0,1}`,
/// defaulting to stuck-at-1).
fn parse_stuck(spec: &str) -> Result<(usize, usize, bool)> {
    let parts: Vec<&str> = spec.split(',').collect();
    anyhow::ensure!(parts.len() == 2 || parts.len() == 3, "--inject-stuck wants R,C or R,C,V, got '{spec}'");
    let row = parts[0].trim().parse().with_context(|| format!("bad row in --inject-stuck '{spec}'"))?;
    let col = parts[1].trim().parse().with_context(|| format!("bad column in --inject-stuck '{spec}'"))?;
    let value = match parts.get(2).map(|v| v.trim()) {
        None | Some("1") => true,
        Some("0") => false,
        Some(other) => bail!("bad stuck value '{other}' in --inject-stuck (0|1)"),
    };
    Ok((row, col, value))
}

fn parse_model(s: &str) -> Result<ModelKind> {
    Ok(match s {
        "baseline" => ModelKind::Baseline,
        "unlimited" => ModelKind::Unlimited,
        "standard" => ModelKind::Standard,
        "minimal" => ModelKind::Minimal,
        other => bail!("unknown model '{other}' (baseline|unlimited|standard|minimal)"),
    })
}

fn cmd_report() -> Result<()> {
    let geom = Geometry::paper(64)?;
    println!("PartitionPIM control & periphery report (n={}, k={}, NOT/NOR)\n", geom.n, geom.k);

    println!("Control-message formats vs combinatorial lower bounds (E2-E5):");
    println!("{:<11} {:>12} {:>13}  operation count", "model", "format bits", "lower bound");
    for r in figures::control_table(&geom) {
        let count = if r.operation_count_decimal.len() > 32 {
            format!("{}... ({} digits)", &r.operation_count_decimal[..24], r.operation_count_decimal.len())
        } else {
            r.operation_count_decimal.clone()
        };
        println!("{:<11} {:>12} {:>13}  {}", r.model.name(), r.format_bits, r.lower_bound_bits, count);
    }

    println!("\nPeriphery structural cost (E12):");
    println!("{:<22} {:>12} {:>13} {:>12}", "design", "CMOS gates", "analog muxes", "extra logic");
    for r in figures::periphery_table(&geom) {
        println!("{:<22} {:>12} {:>13} {:>12}", r.name, r.area.cmos_gates, r.area.analog_muxes, r.area.extra_logic_gates);
    }

    println!("\nIsolation-transistor area overhead: {:.2}% (paper cites ~3% [8])", 100.0 * figures::transistor_overhead(&geom));
    Ok(())
}

fn cmd_figure6() -> Result<()> {
    println!("Figure 6 — 32-bit multiplication, n=1024, k=32 (paper values in parens)\n");
    println!(
        "{:<11} {:>8} {:>12} {:>9} {:>10} {:>9} {:>10} {:>10}",
        "model", "cycles", "speedup", "msg bits", "ctrl x", "memrist.", "area x", "energy x"
    );
    let paper = |m: ModelKind| match m {
        ModelKind::Baseline => ("1.0", "1.0", "1.00", "1.0"),
        ModelKind::Unlimited => ("11.3", "20.2", "~1.4", "2.1"),
        ModelKind::Standard => ("9.2", "2.6", "~1.4", "2.1"),
        ModelKind::Minimal => ("8.6", "1.2", "~1.4", "2.1"),
    };
    for r in figures::figure6()? {
        let p = paper(r.model);
        println!(
            "{:<11} {:>8} {:>5.1}x ({:>4}) {:>9} {:>4.1} ({:>4}) {:>9} {:>4.2} ({:>4}) {:>4.2} ({:>3})",
            r.model.name(),
            r.stats.cycles,
            r.speedup_vs_serial,
            p.0,
            r.message_bits,
            r.control_overhead,
            p.1,
            r.stats.footprint_cols,
            r.area_ratio,
            p.2,
            r.energy_ratio,
            p.3,
        );
    }
    println!("\nMultiplication scaling (N, serial cycles, partitioned cycles, speedup):");
    for (n, s, p, sp) in figures::mult_scaling()? {
        println!("  N={n:<3} serial={s:<7} partitioned={p:<6} speedup={sp:.2}x");
    }
    Ok(())
}

fn cmd_sweep() -> Result<()> {
    println!("Partition-count sweep — the paper's central trade-off (n=1024):\n");
    println!("{:>4} {:>9} {:>10} {:>9} {:>9} {:>12}", "k", "speedup", "unlimited", "standard", "minimal", "transistors");
    for r in figures::partition_sweep()? {
        println!(
            "{:>4} {:>8.2}x {:>7} bits {:>5} bits {:>4} bits {:>11.2}%",
            r.k,
            r.speedup,
            r.bits_unlimited,
            r.bits_standard,
            r.bits_minimal,
            100.0 * r.transistor_overhead
        );
    }
    println!("\n(speedup and unlimited-message length both grow with k; the minimal");
    println!(" design keeps control near the 30-bit baseline at every scale)");
    Ok(())
}

fn cmd_sort() -> Result<()> {
    println!("Sorting speedup (E10; paper intro cites 14x at 16 partitions [1]):\n");
    println!("{:>6} {:>7} {:>14} {:>19} {:>9}", "elems", "w bits", "serial cycles", "partitioned cycles", "speedup");
    for r in figures::sort_table(6)? {
        println!("{:>6} {:>7} {:>14} {:>19} {:>8.2}x", r.elems, r.w_bits, r.serial_cycles, r.partitioned_cycles, r.speedup);
    }
    Ok(())
}

/// `repro sha3`: the HashPIM workload demo. Prints the per-step cycle/gate
/// table of one Keccak round against the published HashPIM budget, then
/// runs full Keccak-f[1600] permutations through the serving worker (wire
/// pipeline, decode-once replay) and checks every state against the
/// software oracle.
fn cmd_sha3(flags: &HashMap<String, String>) -> Result<()> {
    use partition_pim::coordinator::worker::Worker;

    let model = parse_model(flags.get("model").map(String::as_str).unwrap_or("minimal"))?;
    let rows: usize = flags.get("rows").map(String::as_str).unwrap_or("4").parse()?;
    let geom = workload_geometry(WorkloadKind::Sha3, model, rows)?;
    let unit = sha3::build_keccak_f(geom)?;

    println!("SHA-3 (HashPIM) Keccak-f[1600] on n={}, k={} (one partition per lane bit), {} model\n", geom.n, geom.k, model.name());
    println!("{:<7} {:>12} {:>12} {:>16} {:>16}", "step", "cycles", "gates", "published cyc", "published gates");
    for ((name, s), (pname, pc, pg)) in unit.round_stats.steps().into_iter().zip(sha3::PUBLISHED_STEP_TABLE) {
        debug_assert_eq!(name, pname);
        println!("{:<7} {:>12} {:>12} {:>16} {:>16}", name, s.cycles, s.gates, pc, pg);
    }
    let t = unit.round_stats.total();
    println!(
        "{:<7} {:>12} {:>12} {:>16} {:>16}",
        "round", t.cycles, t.gates, sha3::PUBLISHED_ROUND_CYCLES, sha3::PUBLISHED_ROUND_GATES
    );
    anyhow::ensure!(t.cycles <= sha3::PUBLISHED_ROUND_CYCLES, "round latency exceeds the published budget");
    println!(
        "\nround latency {:.2}x under the published budget (z bit-slice: 64 state bits/cycle, native XOR)\n",
        sha3::PUBLISHED_ROUND_CYCLES as f64 / t.cycles as f64
    );

    let mut worker = Worker::new(WorkloadKind::Sha3, model, geom)?;
    let mut seed = 0x9e3779b97f4a7c15u64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        seed
    };
    let states: Vec<[u64; 25]> = (0..rows)
        .map(|_| {
            let mut st = [0u64; 25];
            for lane in st.iter_mut() {
                *lane = rnd();
            }
            st
        })
        .collect();
    let t0 = Instant::now();
    let (out, metrics) = worker.run_sha3_batch(&states)?;
    let wall = t0.elapsed();
    for (r, st) in states.iter().enumerate() {
        let mut want = *st;
        sha3::keccak_f_sw(&mut want);
        anyhow::ensure!(out[r] == want, "crossbar permutation diverged from the software oracle on row {r}");
    }
    println!("{rows} Keccak-f[1600] permutations (24 rounds each), all bitwise-equal to the software oracle");
    println!(
        "sim_cycles={} ({} cycles/round)  control_bits={}  switch_events={}  wall={:?}",
        metrics.cycles,
        metrics.cycles / sha3::ROUNDS as u64,
        metrics.control_bits,
        metrics.switch_events,
        wall
    );
    Ok(())
}

/// `repro serve --banks N`: the fleet demo. N banks cycle through the
/// workload mix; a mixed trace is routed across them by the fleet, with
/// optional mid-trace bank kill to demonstrate rerouting / hot-spare
/// promotion. Every result is verified in-process.
fn cmd_serve_fleet(flags: &HashMap<String, String>) -> Result<()> {
    let model = parse_model(flags.get("model").map(String::as_str).unwrap_or("minimal"))?;
    let n_banks: usize = flags.get("banks").map(String::as_str).unwrap_or("3").parse()?;
    let n_crossbars: usize = flags.get("crossbars").map(String::as_str).unwrap_or("2").parse()?;
    let rows: usize = flags.get("rows").map(String::as_str).unwrap_or("64").parse()?;
    let jobs: usize = flags.get("jobs").map(String::as_str).unwrap_or("12").parse()?;
    let len: usize = flags.get("len").map(String::as_str).unwrap_or("256").parse()?;
    let spares: usize = flags.get("spares").map(String::as_str).unwrap_or("1").parse()?;
    let max_pending: usize = flags.get("max-pending").map(String::as_str).unwrap_or("256").parse()?;
    let kill_bank: Option<usize> = match flags.get("kill-bank") {
        Some(b) => Some(b.parse()?),
        None => None,
    };
    let mix_spec = flags.get("mix").map(String::as_str).unwrap_or("mul:add:sort");
    let mut mix = Vec::new();
    for part in mix_spec.split(':') {
        mix.push(WorkloadKind::parse(part).with_context(|| format!("unknown workload '{part}' in --mix (mul|add|sort|sha3)"))?);
    }

    let base = ServiceConfig { model, n_crossbars, rows, ..Default::default() };
    let mut cfg = FleetConfig::mixed(&mix, n_banks, base)?;
    cfg.spare_slots = spares;
    cfg.max_pending_per_bank = max_pending;
    println!(
        "Starting PIM fleet: {} banks (mix {}), {} crossbars x {} rows each, {} spare(s), admission bound {}",
        n_banks, mix_spec, n_crossbars, rows, spares, max_pending
    );
    let fleet = PimFleet::start(cfg)?;
    let client = fleet.client();

    let mut seed = 0x243f6a8885a308d3u64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        seed
    };
    enum Expect {
        Scalars(Vec<u64>),
        Rows(Vec<Vec<u64>>),
        States(Vec<[u64; 25]>),
    }
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for j in 0..jobs {
        let kind = mix[j % mix.len()];
        let (expect, handle) = match kind.shape() {
            JobShape::ElementWise => {
                let a: Vec<u64> = (0..len).map(|_| rnd() & 0xffff_ffff).collect();
                let b: Vec<u64> = (0..len).map(|_| rnd() & 0xffff_ffff).collect();
                let expect = match kind {
                    WorkloadKind::Mul32 => a.iter().zip(&b).map(|(&x, &y)| x * y).collect(),
                    _ => a.iter().zip(&b).map(|(&x, &y)| x + y).collect(),
                };
                (Expect::Scalars(expect), client.submit(kind, &a, &b)?)
            }
            JobShape::RowVectors => {
                let data: Vec<Vec<u64>> =
                    (0..rows).map(|_| (0..SORT_ELEMS).map(|_| rnd() & ((1 << SORT_BITS) - 1)).collect()).collect();
                let expect = data
                    .iter()
                    .map(|r| {
                        let mut s = r.clone();
                        s.sort_unstable();
                        s
                    })
                    .collect();
                (Expect::Rows(expect), client.submit_sort(&data)?)
            }
            JobShape::KeccakState => {
                let states: Vec<[u64; 25]> = (0..rows)
                    .map(|_| {
                        let mut st = [0u64; 25];
                        for lane in st.iter_mut() {
                            *lane = rnd();
                        }
                        st
                    })
                    .collect();
                let expect = states
                    .iter()
                    .map(|st| {
                        let mut s = *st;
                        sha3::keccak_f_sw(&mut s);
                        s
                    })
                    .collect();
                (Expect::States(expect), client.submit_sha3(&states)?)
            }
        };
        pending.push((j, kind, expect, handle));
        if kill_bank == Some(j) {
            fleet.kill_bank(j % n_banks)?;
            println!("fault    : bank {} killed mid-trace; its jobs reroute (spare promotes)", j % n_banks);
        }
    }
    for (j, kind, expect, handle) in pending {
        let res = handle.wait().with_context(|| format!("job {j} ({})", kind.name()))?;
        match expect {
            Expect::Scalars(want) => anyhow::ensure!(res.try_scalars()? == want.as_slice(), "wrong values in job {j}"),
            Expect::Rows(want) => anyhow::ensure!(res.try_rows()? == want.as_slice(), "wrong rows in job {j}"),
            Expect::States(want) => anyhow::ensure!(res.try_states()? == want.as_slice(), "wrong keccak states in job {j}"),
        }
        println!(
            "job {j:>3} ({:<6}): {:>5} values  sim_cycles={:<8} wall={:?}",
            kind.name(),
            res.values.len(),
            res.sim_cycles,
            res.wall
        );
    }
    let wall = t0.elapsed();
    let stats = fleet.shutdown();
    println!("\nfleet: {} jobs ({} failed) in {:?}", stats.aggregate.jobs, stats.aggregate.failed_jobs, wall);
    println!(
        "routing: {} routed, {} rerouted, {} overloaded, {} no-bank; lifecycle: {} dead, {} promoted, {} spawned, {} retired",
        stats.counters.routed,
        stats.counters.reroutes,
        stats.counters.rejected_overloaded,
        stats.counters.rejected_no_bank,
        stats.counters.banks_dead,
        stats.counters.spares_promoted,
        stats.counters.banks_spawned,
        stats.counters.banks_retired
    );
    for (i, b) in stats.banks.iter().enumerate() {
        println!(
            "bank {i} ({:<6} {:?}): {} jobs, {} elements, {:.1}% mean occupancy",
            b.kind.name(),
            b.state,
            b.stats.jobs,
            b.stats.elements,
            100.0 * b.stats.mean_occupancy()
        );
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    if flags.contains_key("banks") {
        return cmd_serve_fleet(flags);
    }
    let model = parse_model(flags.get("model").map(String::as_str).unwrap_or("minimal"))?;
    let n_crossbars: usize = flags.get("crossbars").map(String::as_str).unwrap_or("4").parse()?;
    let rows: usize = flags.get("rows").map(String::as_str).unwrap_or("64").parse()?;
    let jobs: usize = flags.get("jobs").map(String::as_str).unwrap_or("8").parse()?;
    let len: usize = flags.get("len").map(String::as_str).unwrap_or("256").parse()?;
    let inject_bad = flags.contains_key("inject-bad");
    let coalescing = !flags.contains_key("no-coalesce");
    let replay_mode = if flags.contains_key("wire-replay") { ReplayMode::Wire } else { ReplayMode::Decoded };
    let replay_threads: usize = flags.get("replay-threads").map(String::as_str).unwrap_or("1").parse()?;
    let wear_leveling = !flags.contains_key("no-wear-level");
    let endurance_budget: Option<u64> = match flags.get("endurance-budget") {
        Some(b) => Some(b.parse()?),
        None => None,
    };
    let inject_stuck: Option<(usize, usize, bool)> = match flags.get("inject-stuck") {
        Some(spec) => Some(parse_stuck(spec)?),
        None => None,
    };
    let kill: Option<usize> = match flags.get("kill") {
        Some(w) => Some(w.parse()?),
        None => None,
    };

    println!(
        "Starting PIM service: model={}, {} crossbars x {} rows, coalescing {}, replay {}",
        model.name(),
        n_crossbars,
        rows,
        if coalescing { "on" } else { "off" },
        match replay_mode {
            ReplayMode::Decoded => format!("decoded x{replay_threads}"),
            ReplayMode::Wire => "wire".to_string(),
        }
    );
    let svc = PimService::start(ServiceConfig {
        kind: WorkloadKind::Mul32,
        model,
        n_crossbars,
        rows,
        coalescing,
        replay_mode,
        replay_threads,
        wear_leveling,
        endurance_budget,
        ..Default::default()
    })?;
    println!("batch latency: {} crossbar cycles\n", svc.batch_cycles);

    let t0 = Instant::now();
    let mut seed = 0x243f6a8885a308d3u64;
    let mut rnd = move || {
        seed ^= seed << 13;
        seed ^= seed >> 7;
        seed ^= seed << 17;
        seed & 0xffff_ffff
    };
    // Pipelined submission: every job is in flight before the first result
    // is read back — the scheduler keeps the whole bank saturated.
    let mut pending = Vec::new();
    for _ in 0..jobs {
        let a: Vec<u64> = (0..len).map(|_| rnd()).collect();
        let b: Vec<u64> = (0..len).map(|_| rnd()).collect();
        let handle = svc.submit(&a, &b)?;
        pending.push((a, b, handle));
    }
    if inject_bad {
        // One tenant misbehaves: an operand outside the 32-bit range. The
        // job fails; every other job on the bank is unaffected.
        let handle = svc.submit(&[1u64 << 33, 5], &[3, 4])?;
        match handle.wait() {
            Ok(_) => anyhow::bail!("malformed job unexpectedly succeeded"),
            Err(e) => println!("bad job  : rejected in isolation ({e:#})"),
        }
    }
    if let Some((row, col, value)) = inject_stuck {
        svc.inject_stuck(row, col, value)?;
        println!("fault    : cell ({row},{col}) stuck at {} mid-service; the row quarantines, segments remap", value as u8);
    }
    if let Some(w) = kill {
        svc.kill_worker(w)?;
        println!("fault    : worker {w} killed mid-service; its chunks requeue to the survivors");
    }
    for (j, (a, b, handle)) in pending.into_iter().enumerate() {
        let res = handle.wait()?;
        let vals = res.try_scalars()?;
        for i in 0..len {
            anyhow::ensure!(vals[i] == a[i] * b[i], "wrong product at job {j} element {i}");
        }
        println!(
            "job {j:>3}: {len} elements  sim_cycles={:<8} control={:>7} bits  wall={:?}",
            res.sim_cycles, res.control_bits, res.wall
        );
    }
    let wall = t0.elapsed();
    let stats = svc.shutdown();
    let elems = stats.elements as f64;
    println!("\n{} jobs ({} failed), {} elements in {:?}", stats.jobs, stats.failed_jobs, stats.elements, wall);
    println!(
        "throughput: {:.0} mults/s (wall)  |  {:.2} elements/kilocycle (simulated)",
        elems / wall.as_secs_f64(),
        1000.0 * elems / stats.metrics.cycles as f64
    );
    println!("control traffic: {} bits total ({:.1} bits/element)", stats.metrics.control_bits, stats.metrics.control_bits as f64 / elems);
    println!("energy proxy: {} gate events, {} switch events", stats.metrics.gate_events, stats.metrics.switch_events);
    println!(
        "bank utilization: {} batches, {:.1}% mean row occupancy ({} of {} rows carried operands)",
        stats.batches,
        100.0 * stats.mean_occupancy(),
        stats.occupied_rows,
        stats.capacity_rows
    );
    let w = &stats.wear;
    println!(
        "wear: max {} / mean {:.1} switch events per row, gini {:.3}, {} row(s) quarantined, {} segment remap(s)",
        w.max_row_wear, w.mean_row_wear, w.wear_gini, w.quarantined_rows, stats.remapped_segments
    );
    if w.endurance_budget > 0 {
        if w.projected_ttff_secs.is_finite() {
            println!(
                "endurance: budget {} switches/row -> first row failure projected in {:.1}s at this load",
                w.endurance_budget, w.projected_ttff_secs
            );
        } else {
            println!("endurance: budget {} switches/row -> no row wearing, no projected failure", w.endurance_budget);
        }
    }
    Ok(())
}

/// `repro lint`: run the static verifier over every built-in workload
/// program × control model pair (the same programs the coordinator serves).
/// Exits nonzero on any error-severity diagnostic — the CI gate that keeps
/// the built-in algorithm library conforming to the paper's reduced
/// operation sets. `--all` is accepted for explicitness (the full sweep is
/// the default); `--model M` restricts to one model; `--deny-warnings`
/// upgrades warnings to failures.
fn cmd_lint(flags: &HashMap<String, String>) -> Result<()> {
    let deny_warnings = flags.contains_key("deny-warnings");
    let model_filter = match flags.get("model") {
        Some(m) => Some(parse_model(m)?),
        None => None,
    };
    let kinds = [
        (WorkloadKind::Mul32, "mul32"),
        (WorkloadKind::Add32, "add32"),
        (WorkloadKind::Sort16, "sort16"),
        (WorkloadKind::Sha3, "sha3"),
    ];
    println!("verifier lint: built-in workload programs x control models\n");
    println!("{:<20} {:>7} {:>26} {:>7} {:>6} {:>6}", "program", "cycles", "serial/par/semi/init", "errors", "warns", "notes");
    let (mut errors, mut warnings, mut pairs) = (0usize, 0usize, 0usize);
    for (kind, kname) in kinds {
        for model in ModelKind::ALL {
            if let Some(m) = model_filter {
                if m != model {
                    continue;
                }
            }
            let geom = workload_geometry(kind, model, 4)?;
            let (program, _) =
                compile_workload(kind, model, geom).with_context(|| format!("compiling {kname} for the {} model", model.name()))?;
            let report = verify::verify_program(&program, model);
            let p = report.profile;
            println!(
                "{:<20} {:>7} {:>26} {:>7} {:>6} {:>6}",
                format!("{kname}@{}", model.name()),
                report.cycles,
                format!("{}/{}/{}/{}", p.serial, p.parallel, p.semi_parallel, p.init),
                report.error_count(),
                report.warning_count(),
                report.info_count(),
            );
            for d in report.diagnostics.iter().filter(|d| d.severity >= Severity::Warning).take(20) {
                println!("    {d}");
            }
            errors += report.error_count();
            warnings += report.warning_count();
            pairs += 1;
        }
    }
    println!();
    if errors > 0 || (deny_warnings && warnings > 0) {
        bail!("lint failed: {errors} error(s), {warnings} warning(s) across {pairs} workload x model pairs");
    }
    println!("lint clean: 0 errors, {warnings} warning(s) across {pairs} workload x model pairs");
    Ok(())
}

fn cmd_xla_parity(flags: &HashMap<String, String>) -> Result<()> {
    let dir = PathBuf::from(flags.get("artifacts").map(String::as_str).unwrap_or("artifacts"));
    let n: usize = flags.get("n").map(String::as_str).unwrap_or("256").parse()?;
    let k: usize = flags.get("k").map(String::as_str).unwrap_or("8").parse()?;
    let rows: usize = flags.get("rows").map(String::as_str).unwrap_or("16").parse()?;
    let geom = Geometry::new(n, k, rows)?;
    println!("XLA parity check on n={n}, k={k}, rows={rows} (artifact dir {})", dir.display());

    let mult = build_multpim(geom, MultPimVariant::Plain)?;
    let mut sim = Crossbar::new(geom, GateSet::NotNor);
    let mut expect = Vec::new();
    let mut seed = 99u64;
    for r in 0..rows {
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        let a = (seed >> 33) & ((1 << k) - 1);
        let b = (seed >> 11) & ((1 << k) - 1);
        mult.load(&mut sim.state, r, a, b)?;
        expect.push(a * b);
    }
    let mut xla = XlaCrossbar::new(geom, &dir).context("loading step artifact (run `make artifacts`)")?;
    xla.load_state(&sim.state)?;

    // The same program object runs both backends through the same pipeline
    // API — that is the whole point of the PimBackend seam.
    let t0 = Instant::now();
    mult.program.execute(&mut ExecPipeline::direct(&mut sim))?;
    let t_sim = t0.elapsed();
    let t1 = Instant::now();
    mult.program.execute(&mut ExecPipeline::direct(&mut xla))?;
    let t_xla = t1.elapsed();

    let xb = xla.state_bits()?;
    anyhow::ensure!(xb == sim.state, "XLA backend state diverged from the bit-packed simulator");
    for r in 0..rows {
        anyhow::ensure!(mult.read_product(&sim.state, r)? == expect[r], "bad product row {r}");
    }
    println!("parity OK over {} cycles ({} rows)", mult.program.ops.len(), rows);
    println!("bit-packed sim: {t_sim:?}   XLA backend: {t_xla:?}");
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "report" => cmd_report(),
        "figure6" => cmd_figure6(),
        "sweep" => cmd_sweep(),
        "sort" => cmd_sort(),
        "sha3" => cmd_sha3(&flags),
        "serve" => cmd_serve(&flags),
        "lint" => cmd_lint(&flags),
        "xla-parity" => cmd_xla_parity(&flags),
        _ => {
            println!("PartitionPIM reproduction driver\n");
            println!("usage: repro <report|figure6|sweep|sort|sha3|serve|lint|xla-parity> [--flag value]...");
            println!("  report      control formats, lower bounds, periphery areas");
            println!("  figure6     regenerate Figure 6 (latency / control / area / energy)");
            println!("  sweep       speedup vs control-overhead across partition counts");
            println!("  sort        sorting speedup table");
            println!("  sha3        Keccak-f[1600] round demo: per-step cycle/gate table vs the");
            println!("              published HashPIM budget, full permutation vs software oracle");
            println!("              [--model minimal] [--rows 4]");
            println!("  serve       end-to-end vector-multiply service demo (concurrent scheduler)");
            println!("              [--model minimal] [--crossbars 4] [--rows 64] [--jobs 8] [--len 256]");
            println!("              [--inject-bad]  submit one malformed job, show fault isolation");
            println!("              [--kill W]      kill worker W mid-service, show chunk requeue");
            println!("              [--no-coalesce] disable cross-job chunk coalescing (ablation)");
            println!("              [--no-wear-level] disable cold-row wear-leveling placement (ablation)");
            println!("              [--endurance-budget N] per-row switch budget for the TTFF projection");
            println!("              [--inject-stuck R,C[,V]] stick cell (R,C) mid-service; quarantine + remap");
            println!("              --banks N       fleet mode: N banks cycling through --mix");
            println!("              [--mix mul:add:sort:sha3] workload mix across the banks");
            println!("              [--spares 1]    hot-spare slots promoted on bank death");
            println!("              [--max-pending 256] per-bank admission bound (backpressure)");
            println!("              [--kill-bank B] kill bank B mid-trace, show rerouting");
            println!("  lint        statically verify every built-in workload program against");
            println!("              every control model; exits nonzero on error diagnostics");
            println!("              [--all] [--model M] [--deny-warnings]");
            println!("  xla-parity  rust simulator vs AOT XLA artifact cross-check");
            println!("              [--artifacts artifacts] [--n 256] [--k 8] [--rows 16]");
            Ok(())
        }
    }
}
